"""Launchers."""
