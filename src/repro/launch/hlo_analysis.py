"""Loop-aware HLO cost analysis for the dry-run.

XLA's cost_analysis() counts while-loop bodies ONCE; these helpers parse
the post-SPMD HLO text, recover per-computation execution multipliers
from the compiler's known_trip_count annotations, and produce
loop-corrected collective-byte totals (the roofline's collective term).
Also quantifies the CPU backend's bf16->f32 dot-upcast artifact so
memory numbers can be TPU-projected."""
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|((?:[a-z0-9]+)\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
    r"(?:.*?\"known_trip_count\":\{\"n\":\"(\d+)\"\})?")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """{computation_name: body_text} from post-optimization HLO."""
    comps = {}
    cur_name, cur_lines, depth = None, [], 0
    for line in hlo_text.splitlines():
        if cur_name is None:
            m = _COMP_HEAD_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur_name, cur_lines, depth = m.group(1), [], 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
        else:
            cur_lines.append(line)
    return comps


def _loop_multipliers(comps: dict) -> dict:
    """Per-computation execution-count multiplier: while bodies run
    trip_count times (XLA's cost_analysis counts them ONCE — this is the
    correction).  Trip counts come from the compiler's own
    ``known_trip_count`` backend_config on each while op; fallback is the
    largest integer constant in the loop condition."""
    whiles = {name: _WHILE_RE.findall(text) for name, text in comps.items()}
    mult = {name: 0 for name in comps}
    referenced = set()
    for ws in whiles.values():
        for c, b, _t in ws:
            referenced.add(c)
            referenced.add(b)
    roots = [n for n in comps if n not in referenced]

    def visit(name, m):
        if name not in comps or m <= mult.get(name, 0):
            return
        mult[name] = m
        for cond, body, trip_s in whiles.get(name, ()):
            if trip_s:
                trip = int(trip_s)
            else:
                consts = [int(c) for c in
                          _CONST_RE.findall(comps.get(cond, ""))]
                trip = max(consts) if consts else 1
            visit(cond, m * trip)
            visit(body, m * trip)

    for r in roots:
        visit(r, 1)
    return mult


_UPCAST_RE = re.compile(
    r"ROOT %convert[^=]*= f32\[([0-9,]+)\][^ ]* convert\(%param")


def cpu_dot_upcast_bytes(hlo_text: str) -> int:
    """Bytes of hoisted bf16->f32 whole-weight conversions.  The CPU
    backend has no native bf16 dot, so XLA converts weight stacks to f32
    before the layer loop; a real TPU consumes bf16 on the MXU directly.
    The roofline subtracts this from temp_bytes as a documented
    CPU-artifact correction."""
    total = 0
    for m in _UPCAST_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            if d:
                n *= int(d)
        total += n * 4
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT sizes of collective ops in post-SPMD HLO, per op kind,
    LOOP-AWARE: ops inside while bodies are multiplied by the loop trip
    count (scan-over-layers etc.).  Result size == payload moved per
    device for AG/AR; adequate roofline proxy for all five kinds."""
    comps = _split_computations(hlo_text)
    mults = _loop_multipliers(comps)
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for name, text in comps.items():
        m = mults.get(name, 1) or 1
        for match in _COLL_RE.finditer(text):
            shape_str = match.group(1) or match.group(2)
            kind = match.group(3)
            out[kind] = out.get(kind, 0) + _shape_bytes(shape_str) * m
            count[kind] = count.get(kind, 0) + m
    return {"bytes": out, "count": count,
            "total_bytes": int(sum(out.values()))}


