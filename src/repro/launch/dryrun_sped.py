import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# device-count override must precede every other import (see dryrun.py).
_DOC = """Dry-run for the PAPER'S OWN workload at production scale: one
distributed SPED solver step (series-transformed Laplacian operator +
mu-EigenGame update) on a synthetic web-scale graph, lowered and compiled
for the 16x16 pod and the 2x16x16 multi-pod mesh.

Graph stand-in: n = 2^22 nodes, E = 2^26 edges (ShapeDtypeStruct only —
never materialized).  Edges are sharded over ("pod","data") x "model"
(every chip owns an edge shard); the eigenvector panel V (n, k) is
replicated.  Each Laplacian matvec = local edge gather/segment-sum + one
all-reduce of the panel, so a degree-d series costs d panel all-reduces —
the collective-dominant regime the perf loop then attacks:

  variants (the #Perf iteration ladder):
    limit251        — paper-faithful: -(I - L/251)^251, f32 panel
                      (2 scatter-adds per matvec -> 2 f32 ARs each)
    cheb64          — beyond-paper 1: Chebyshev(64) of -e^{-tau x} (same
                      spectral accuracy at ~4x fewer matvecs/psums)
    cheb64_fused    — beyond-paper 2: + single fused scatter per matvec
                      (concat src/dst indices) -> 1 AR per matvec
    cheb64_bf16     — beyond-paper 3: + shard_map matvec with an EXPLICIT
                      bf16 psum (XLA upcasts scatter-add all-reduces to
                      f32 otherwise) -> halves the payload again

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_sped --variant cheb64 \
      --mesh both --out experiments/dryrun
"""

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.launch.mesh import make_production_mesh
from repro.launch import dryrun as dr
from repro.core import series as series_lib
from repro.core import solvers

SDS = jax.ShapeDtypeStruct

N_NODES = 1 << 22
N_EDGES = 1 << 26
K = 32
RHO_UB = 64.0  # spectral-radius bound fed to the scaled/cheb variants


def edge_specs():
    return {
        "src": SDS((N_EDGES,), jnp.int32),
        "dst": SDS((N_EDGES,), jnp.int32),
        "weight": SDS((N_EDGES,), jnp.float32),
    }


def make_series(variant: str):
    if variant == "limit251":
        return series_lib.limit_neg_exp(251, scale=8.0 / RHO_UB)
    if variant.startswith("cheb64"):
        return series_lib.cheb_neg_exp(64, rho=RHO_UB, tau=8.0 / RHO_UB)
    raise ValueError(variant)


def build_step(variant: str, mesh, edge_axes, lr: float = 0.1):
    s = make_series(variant)
    panel_dtype = jnp.bfloat16 if variant.endswith("bf16") else jnp.float32

    def matvec_2scatter(edges, u):
        # baseline: two scatter-adds -> GSPMD emits 2 f32 all-reduces
        w = edges["weight"].astype(u.dtype)
        diff = u[edges["src"]] - u[edges["dst"]]
        wdiff = w[:, None] * diff
        out = jnp.zeros_like(u)
        out = out.at[edges["src"]].add(wdiff)
        out = out.at[edges["dst"]].add(-wdiff)
        return out

    def matvec_fused(edges, u):
        # one concatenated scatter -> 1 all-reduce per matvec
        w = edges["weight"].astype(u.dtype)
        diff = u[edges["src"]] - u[edges["dst"]]
        wdiff = w[:, None] * diff
        idx = jnp.concatenate([edges["src"], edges["dst"]])
        upd = jnp.concatenate([wdiff, -wdiff])
        return jnp.zeros_like(u).at[idx].add(upd)

    if variant.endswith("bf16"):
        import functools as ft
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as P

        @ft.partial(shard_map, mesh=mesh,
                    in_specs=(P(edge_axes), P(edge_axes), P(edge_axes), P()),
                    out_specs=P())
        def mv_sm(src, dst, w, u):
            diff = u[src] - u[dst]
            wdiff = w.astype(u.dtype)[:, None] * diff
            idx = jnp.concatenate([src, dst])
            upd = jnp.concatenate([wdiff, -wdiff])
            out = jnp.zeros_like(u).at[idx].add(upd)
            return jax.lax.psum(out, edge_axes)  # EXPLICIT bf16 psum

        def matvec(edges, u):
            return mv_sm(edges["src"], edges["dst"], edges["weight"], u)
    elif variant.endswith("fused"):
        matvec = matvec_fused
    else:
        matvec = matvec_2scatter

    def step(v, edges):
        av = s.apply_reversed(
            lambda u: matvec(edges, u), v.astype(panel_dtype))
        state = solvers.SolverState(v=v, step=jnp.zeros((), jnp.int32))
        return solvers.mu_eg_step(state, av.astype(jnp.float32), lr).v

    return step


def run_cell(variant: str, multi_pod: bool):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    edge_axes = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)
    with compat.set_mesh(mesh):
        v_sds = SDS((N_NODES, K), jnp.float32)
        e_sh = {k: NamedSharding(mesh, P(edge_axes))
                for k in ("src", "dst", "weight")}
        fn = jax.jit(build_step(variant, mesh, edge_axes),
                     in_shardings=(NamedSharding(mesh, P()), e_sh),
                     donate_argnums=(0,))
        lowered = fn.lower(v_sds, edge_specs())
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = dr.collective_bytes(hlo)
    s = make_series(variant)
    devices = int(np.prod(list(mesh.shape.values())))
    # analytic terms: degree matvecs of O(E/devices * K) gather/scatter +
    # K*N panel ops; compute is the edge segment sums
    flops = s.degree * (6.0 * N_EDGES * K) / devices
    hbm = s.degree * (N_EDGES * (3 * 4 + 2 * 4 * K) / devices
                      + 2 * N_NODES * K * 4)
    return {
        "arch": f"sped-graph-{variant}",
        "shape": f"n{N_NODES >> 20}M_e{N_EDGES >> 20}M_k{K}",
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok", "kind": "sped_step",
        "devices": devices,
        "seconds": round(time.time() - t0, 1),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "analytic": {"flops_per_dev": flops, "hbm_bytes_per_dev": hbm,
                     "degree": s.degree},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "collectives": coll,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="all",
                    choices=["limit251", "cheb64", "cheb64_fused",
                             "cheb64_bf16", "all"])
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)
    variants = (["limit251", "cheb64", "cheb64_fused", "cheb64_bf16"]
                if args.variant == "all" else [args.variant])
    meshes = [False, True] if args.mesh == "both" else \
        [args.mesh == "multipod"]
    for var in variants:
        for mp in meshes:
            res = run_cell(var, mp)
            tag = f"sped__{var}__{'multipod' if mp else 'pod'}"
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
            c = res["collectives"]
            print(f"[sped-dryrun] {tag}: coll={c['total_bytes']:.3g}B "
                  f"(AR count {c['count'].get('all-reduce', 0)}) "
                  f"temp={res['memory']['temp_bytes']}", flush=True)


if __name__ == "__main__":
    main()
