"""Partitioning rules: map every param / optimizer / cache tensor to a
PartitionSpec for the production mesh.

Policy (DESIGN.md Sec. 5):
  * TP ("model"): attention heads, FFN hidden, MoE experts (EP), Mamba2
    heads, vocab dim of the embedding tables.
  * DP ("pod", "data"): batch dims of activations/caches; FSDP-sharding
    of params + optimizer moments for archs whose per-TP-shard params
    exceed `FSDP_THRESHOLD` bytes (XLA inserts the per-layer all-gathers
    inside the layer scan = classic ZeRO-3 streaming).
  * ZeRO-1 moments: additionally sharded over DP on the first free,
    divisible dim.
  * every rule degrades to replication when the dim is not divisible by
    the mesh extent (never crashes on an odd head count).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

# params bigger than this per TP shard get FSDP over the dp axes
FSDP_THRESHOLD = 3 * 2 ** 30


def mesh_axes(mesh: Mesh):
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    return dp, tp


def _extent(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    e = 1
    for a in axes:
        e *= mesh.shape[a]
    return e


# --------------------------------------------------------------------------
# Param rules
# --------------------------------------------------------------------------

# (match-substrings, base_spec builder) — first match wins.  Specs are for
# the UNSTACKED layer tensor; a leading layer axis gets None prepended.
def _param_rule(names: tuple[str, ...]) -> tuple[str | None, ...]:
    """Returns per-dim logical axes for the UNSTACKED tensor, rightmost
    dims aligned ('tp' on the dim noted)."""
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    if name == "table":  # embed/unembed (vocab, d)
        return ("tp", "fsdp")
    if name in ("wq", "wk", "wv", "w_kv_up"):
        return ("fsdp", "tp")
    if name == "wo":
        return ("tp", "fsdp")
    if name in ("bq", "bk", "bv"):
        return ("tp",)
    if name in ("w_gate", "w_up"):
        if parent in ("moe",) or len(names) >= 2 and names[-2] == "moe":
            return ("tp", "fsdp", None)
        return ("fsdp", "tp")
    if name == "w_down":
        if parent in ("moe",):
            return ("tp", "fsdp", None)
        return ("tp", "fsdp")
    if name == "router":
        return (None, None)
    if name in ("w_kv_down", "w_k_rope"):
        return ("fsdp", None)
    if name == "w_zx":
        return ("fsdp", "tp")
    if name == "w_bcdt":
        return ("fsdp", None)
    if name == "conv_w_x":
        return (None, "tp")
    if name == "conv_b_x":
        return ("tp",)
    if name == "w_out":  # ssm out proj (d_in, d)
        return ("tp", "fsdp")
    return tuple(None for _ in ())  # scalar/1d -> replicated (filled later)


def _is_moe_leaf(path_names):
    return "moe" in path_names or (
        "shared" in path_names and "moe" not in path_names and False)


def param_specs(cfg: ArchConfig, params_shapes, mesh: Mesh,
                fsdp: bool | None = None):
    """Pytree of PartitionSpec matching `params_shapes` (shapes from
    jax.eval_shape(init))."""
    dp, tp = mesh_axes(mesh)
    if fsdp is None:
        tp_ext = _extent(mesh, tp)
        total_bytes = sum(
            int(np.prod(l.shape)) * 4 for l in jax.tree.leaves(params_shapes))
        fsdp = total_bytes / max(tp_ext, 1) > FSDP_THRESHOLD

    def one(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        shape = leaf.shape
        # moe expert tensors: 3d (E, d, f) — expert dim EP-sharded
        if "moe" in names and names[-1] in ("w_gate", "w_up", "w_down") \
                and "shared" not in names:
            base = ("tp", "fsdp", None)
        else:
            base = _param_rule(names)
        # align base to the rightmost dims (stacked layer axes lead)
        spec: list = [None] * len(shape)
        for i, ax in enumerate(base):
            di = len(shape) - len(base) + i
            if di < 0:
                continue
            if ax == "tp" and tp and shape[di] % _extent(mesh, tp) == 0:
                spec[di] = tp
            elif ax == "fsdp" and fsdp and dp and \
                    shape[di] % _extent(mesh, dp) == 0:
                spec[di] = dp if len(dp) > 1 else dp[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def moment_specs(param_spec_tree, params_shapes, mesh: Mesh):
    """ZeRO-1: moments = param spec + dp sharding on the first free,
    divisible dim (if params aren't already dp-sharded)."""
    dp, _ = mesh_axes(mesh)
    dp_ext = _extent(mesh, dp)

    def one(spec: P, leaf):
        if not dp or dp_ext == 1:
            return spec
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for e in entries:
            if e is None:
                continue
            for a in (e if isinstance(e, tuple) else (e,)):
                used.add(a)
        if any(a in used for a in dp):
            return spec  # already dp-sharded (fsdp)
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] % dp_ext == 0 and leaf.shape[i] > 0:
                entries[i] = dp if len(dp) > 1 else dp[0]
                return P(*entries)
        return spec

    return jax.tree.map(one, param_spec_tree, params_shapes)


# --------------------------------------------------------------------------
# Activation / batch / cache rules
# --------------------------------------------------------------------------

def batch_specs(mesh: Mesh, batch_shapes):
    """tokens/labels (b, s) + stub frontends (b, s, d): batch over dp."""
    dp, _ = mesh_axes(mesh)

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if dp and leaf.shape[0] % _extent(mesh, dp) == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
        return P(*spec)

    return jax.tree.map(one, batch_shapes)


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shapes):
    """Serve-state shardings (typed dispatch over the cache NamedTuples —
    pytree paths don't carry NamedTuple field names).  Leading axis is
    the stacked layer axis; batch then sequence follow:
      KV k/v (L, b, s, kv, hd):  b->dp, s->model (context parallel; this
                                 is what makes 128 x 32k caches fit)
      MLA c_kv (L, b, s, r):     b->dp, s->model
      SSM state (L, b, h, p, n): b->dp, h->model
      cross_kv (L, b, se, h, hd): b->dp, h->model
    Dims not divisible by the mesh extent fall back to replication.
    """
    from repro.models.attention import KVCache, MLACache
    from repro.models.model import ServeState
    from repro.models.ssm import SSMCache
    dp, tp = mesh_axes(mesh)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if dp else None
    tp_ext = _extent(mesh, tp)
    dp_ext = _extent(mesh, dp)

    def dim(shape, i, logical):
        if i >= len(shape):
            return None
        if logical == "dp" and dp and shape[i] % dp_ext == 0:
            return dp_ax
        if logical == "tp" and tp and shape[i] % tp_ext == 0:
            return tp
        return None

    def mk(leaf, logicals):
        """logicals: per-dim logical axis names aligned to leaf dims."""
        if leaf is None:
            return None
        shape = leaf.shape
        spec = [dim(shape, i, l) if l else None
                for i, l in enumerate(logicals[: len(shape)])]
        spec += [None] * (len(shape) - len(spec))
        return P(*spec)

    def kv_cache(c: KVCache):
        # (L, b, s, kv, hd); scales (L, b, s, kv, 1)
        sp = (None, "dp", "tp", None, None)
        return KVCache(
            k=mk(c.k, sp), v=mk(c.v, sp),
            k_scale=mk(c.k_scale, sp), v_scale=mk(c.v_scale, sp),
            length=P())

    def mla_cache(c: MLACache):
        sp = (None, "dp", "tp", None)
        return MLACache(c_kv=mk(c.c_kv, sp), k_rope=mk(c.k_rope, sp),
                        length=P())

    def ssm_cache(c: SSMCache):
        return SSMCache(
            state=mk(c.state, (None, "dp", "tp", None, None)),
            conv_x=mk(c.conv_x, (None, "dp", None, "tp")),
            conv_bc=mk(c.conv_bc, (None, "dp", None, None)),
            length=P())

    def dispatch(c):
        if c is None:
            return None
        if isinstance(c, KVCache):
            return kv_cache(c)
        if isinstance(c, MLACache):
            return mla_cache(c)
        if isinstance(c, SSMCache):
            return ssm_cache(c)
        if isinstance(c, tuple) and not hasattr(c, "_fields"):
            # whisper cross_kv: (k, v) each (L, b, se, h, hd)
            return tuple(mk(x, (None, "dp", None, "tp", None)) for x in c)
        raise TypeError(f"unknown cache node {type(c)}")

    assert isinstance(cache_shapes, ServeState)
    return ServeState(
        caches=dispatch(cache_shapes.caches),
        cross_kv=dispatch(cache_shapes.cross_kv),
        attn_caches=dispatch(cache_shapes.attn_caches),
    )


def to_named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
