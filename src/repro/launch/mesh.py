"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") —
the "pod" axis carries only data parallelism + gradient reduction, so the
only cross-pod (DCN) collective is the once-per-step gradient psum (and
FSDP gathers for the archs that enable it), which is the layout that
scales to 1000+ nodes.

Functions, not module constants: importing this module never touches jax
device state (required for the dry-run's device-count override to work).
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (tests/examples): (1, N) data x model."""
    n = len(jax.devices())
    return compat.make_mesh((n, 1), ("data", "model"))
