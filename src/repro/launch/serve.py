"""Batched serving driver: prefill a batch of prompts, then decode with a
continuous step loop.  CPU-sized by default (--smoke); the production
shardings are exercised by the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
      --smoke --prompt-len 16 --gen 8 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models import model as model_lib
from repro.models.frontends import synthetic_frontend


def serve(args):
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init(key, cfg)
    b = args.batch
    toks = jax.random.randint(jax.random.fold_in(key, 1),
                              (b, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    batch.update(synthetic_frontend(jax.random.fold_in(key, 2), cfg, b))

    max_seq = args.prompt_len + args.gen
    prefill = jax.jit(lambda p, bt: model_lib.prefill(p, cfg, bt,
                                                      max_seq=max_seq))
    decode = jax.jit(lambda p, st, t: model_lib.decode_step(p, cfg, st, t))

    t0 = time.time()
    logits, state = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    for _ in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, state = decode(params, state, tok)
        assert bool(jnp.all(jnp.isfinite(logits))), "decode produced NaNs"
        tok = jnp.argmax(logits, axis=-1)[:, None]
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"prefill {args.prompt_len} toks x{b}: {t_prefill * 1e3:.1f} ms")
    print(f"decode {args.gen} steps: {t_decode * 1e3:.1f} ms "
          f"({args.gen * b / max(t_decode, 1e-9):.1f} tok/s)")
    print("generated:", gen.tolist())
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    serve(args)


if __name__ == "__main__":
    main()
