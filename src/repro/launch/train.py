"""End-to-end training driver.

Two modes share one fault-tolerant loop (checkpoint/auto-resume/retry):

  * ``--mode lm``   — train an assigned-pool architecture (reduced or
    full config) on the synthetic deterministic token pipeline.
  * ``--mode sped`` — the paper's workload: train the eigenvector panel V
    with a stochastic solver on an edge stream (this IS SPED's "training
    loop"; the panel is the model, the edge minibatch is the batch).

Usage (CPU-sized):
  PYTHONPATH=src python -m repro.launch.train --mode sped --steps 600
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen3-4b \
      --smoke --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import logging
import os
import time

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.models import model as model_lib
from repro.models.frontends import synthetic_frontend
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import optimizer as opt_lib

log = logging.getLogger("train")


def train_lm(args):
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
        batch_size, seq = 4, 64
    else:
        batch_size, seq = args.batch, args.seq
    mesh = make_local_mesh()
    opt_cfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps,
                                compress_grads=args.compress_grads)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=batch_size,
                         seq_len=seq, seed=args.seed)

    @jax.jit
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model_lib.train_loss(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = opt_lib.apply(opt_cfg, opt_state, params,
                                              grads)
        return params, opt_state, {**metrics, **om, "loss": loss}

    with compat.set_mesh(mesh):
        params = model_lib.init(jax.random.PRNGKey(args.seed), cfg)
        opt_state = opt_lib.init(opt_cfg, params)
        start = 0
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            (params, opt_state), extra, start = ckpt.restore_with_fallback(
                args.ckpt_dir, (params, opt_state))
            log.info("resumed from step %d", start)

        fe_key = jax.random.PRNGKey(args.seed + 1)
        losses = []
        for step in range(start, args.steps):
            batch = pipe.batch_at(step)
            batch.update(synthetic_frontend(
                jax.random.fold_in(fe_key, step), cfg, batch_size))
            params, opt_state, m = train_step(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if step % args.log_every == 0:
                print(f"step {step} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                fault.retrying(ckpt.save)(
                    args.ckpt_dir, step + 1, (params, opt_state),
                    extra={"loss": float(m["loss"])})
        if args.ckpt_dir:
            fault.retrying(ckpt.save)(args.ckpt_dir, args.steps,
                                      (params, opt_state))
    assert np.isfinite(losses).all(), "training diverged"
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


def train_sped(args):
    """The paper's end-to-end driver: stochastic bottom-k eigensolver on
    a clique graph with the limit-series dilation, checkpointed."""
    from repro.core import (SolverConfig, limit_neg_exp, metrics,
                            operators, run_solver, laplacian_dense,
                            spectral_radius_upper_bound)
    from repro.core import graphs, solvers
    from repro.core.kmeans import cluster_agreement, kmeans

    g, truth = graphs.clique_graph(args.nodes, args.clusters,
                                   seed=args.seed)
    rho = float(spectral_radius_upper_bound(g))
    series = limit_neg_exp(args.degree, scale=args.tau / rho)
    op = operators.minibatch_operator(g, series, batch_edges=args.batch_edges)
    k = args.clusters + 1
    state = solvers.init_state(jax.random.PRNGKey(args.seed), g.num_nodes, k)
    step_fn = jax.jit(
        lambda st, key: solvers.mu_eg_step(st, op(key, st.v), args.lr))

    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (v,), extra, start = ckpt.restore_with_fallback(
            args.ckpt_dir, (state.v,))
        state = solvers.SolverState(v=v, step=jnp.asarray(start, jnp.int32))
        log.info("resumed from step %d", start)

    key = jax.random.PRNGKey(args.seed + 7)
    t0 = time.time()
    for step in range(start, args.steps):
        state = step_fn(state, jax.random.fold_in(key, step))
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            fault.retrying(ckpt.save)(args.ckpt_dir, step + 1, (state.v,))
    jax.block_until_ready(state.v)
    dur = time.time() - t0

    l_dense = laplacian_dense(g)
    _, v_star = metrics.ground_truth_bottom_k(l_dense, k)
    err = float(metrics.subspace_error(state.v, v_star))
    emb = state.v[:, 1: 1 + args.clusters]
    emb = emb / jnp.maximum(jnp.linalg.norm(emb, axis=1, keepdims=True),
                            1e-12)
    labels = kmeans(jax.random.PRNGKey(1), emb, args.clusters).labels
    acc = float(cluster_agreement(labels, jnp.asarray(truth), args.clusters))
    print(f"steps {args.steps - start} in {dur:.1f}s "
          f"({(args.steps - start) / max(dur, 1e-9):.1f} steps/s)")
    print(f"subspace_error {err:.4f} cluster_accuracy {acc:.3f}")
    return err, acc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "sped"], default="sped")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compress-grads", action="store_true")
    # sped
    ap.add_argument("--nodes", type=int, default=200)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--degree", type=int, default=51)
    ap.add_argument("--tau", type=float, default=8.0)
    ap.add_argument("--batch-edges", type=int, default=1024)
    args = ap.parse_args(argv)
    if args.lr is None:
        args.lr = 3e-4 if args.mode == "lm" else 0.1
    logging.basicConfig(level=logging.INFO)
    if args.mode == "lm":
        train_lm(args)
    else:
        train_sped(args)


if __name__ == "__main__":
    main()
