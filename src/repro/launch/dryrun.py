import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why the docstring sits below them
# and no __future__ import is used in this file.
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL program (train_step with optimizer
update, or serve prefill/decode step), jits it with the production
shardings, runs .lower().compile() on 512 placeholder host devices, and
records:
  * memory_analysis()  — proves the cell fits per-device HBM
  * cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective bytes   — parsed from the post-SPMD HLO text per op kind

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import functools
import json
import re
import sys
import time
import traceback

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.launch import shardings as shr
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models.frontends import frontend_spec
from repro.train import optimizer as opt_lib

SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# --------------------------------------------------------------------------

def input_specs(cfg, shape_name: str):
    """Returns (batch_tree, kind) of ShapeDtypeStructs — no allocation."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind in ("train", "prefill"):
        batch = {
            "tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32),
        }
        for name, (fshape, fdtype) in frontend_spec(cfg, b).items():
            batch[name] = SDS(fshape, fdtype)
        if kind == "prefill":
            batch.pop("labels")
        return batch, kind
    # decode: one new token against a cache filled to s
    batch = {"tokens": SDS((b, 1), jnp.int32)}
    return batch, kind


def cache_shapes(cfg, batch: int, max_seq: int):
    """ShapeDtypeStructs of the ServeState via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda: model_lib.init_caches(cfg, batch, max_seq))


# --------------------------------------------------------------------------
# Programs
# --------------------------------------------------------------------------

def build_train_step(cfg, opt_cfg: opt_lib.OptConfig,
                     microbatches: int = 1):
    """Train step with optional gradient accumulation: the global batch is
    split into `microbatches` sequential slices, shrinking the live
    activation checkpoints by the same factor (the fit-lever for >100B
    training on small pods); grads accumulate in param sharding and the
    optimizer applies once."""

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = model_lib.train_loss(p, cfg, batch)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches,
                                     a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def one(acc, bslice):
                (loss, metrics), g = grads_of(params, bslice)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metrics_s) = jax.lax.scan(one, zeros, mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metrics_s)
        params, opt_state, opt_metrics = opt_lib.apply(
            opt_cfg, opt_state, params, grads)
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return train_step


def build_decode_step(cfg):
    def serve_step(params, state, tokens):
        return model_lib.decode_step(params, cfg, state, tokens)

    return serve_step


def build_prefill(cfg, max_seq: int):
    def prefill_step(params, batch):
        return model_lib.prefill(params, cfg, batch, max_seq=max_seq)

    return prefill_step


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

from repro.launch.hlo_analysis import (  # noqa: F401
    collective_bytes, cpu_dot_upcast_bytes, _loop_multipliers,
    _shape_bytes, _split_computations, _COLL_RE)

# --------------------------------------------------------------------------
# One cell
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_overrides: dict | None = None, save_hlo: str | None = None,
             remat: str | None = None, microbatches: int = 1):
    cfg = get_arch(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    if not shape_applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped",
                "reason": "full-attention arch at 524k context "
                          "(DESIGN.md Sec. 4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    batch_sds, kind = input_specs(cfg, shape_name)
    sh = SHAPES[shape_name]

    with compat.set_mesh(mesh):
        params_sds = jax.eval_shape(
            functools.partial(model_lib.init, cfg=cfg), jax.random.PRNGKey(0))
        if kind != "train":
            # SERVING: bf16 weights, TP-only sharding (no FSDP).  FSDP
            # param all-gathers per decoded token were the dominant
            # collective (8.7 GB/step on qwen1.5-32b); resident bf16
            # weights kill them and fit HBM (see EXPERIMENTS.md #Perf).
            params_sds = jax.tree.map(
                lambda l: SDS(l.shape, jnp.bfloat16)
                if jnp.issubdtype(l.dtype, jnp.floating) else l, params_sds)
            p_specs = shr.param_specs(cfg, params_sds, mesh, fsdp=False)
        else:
            p_specs = shr.param_specs(cfg, params_sds, mesh)
        p_shardings = shr.to_named(mesh, p_specs)
        b_shardings = shr.to_named(mesh, shr.batch_specs(mesh, batch_sds))

        if kind == "train":
            opt_cfg = opt_lib.OptConfig(**(opt_overrides or {}))
            opt_sds = jax.eval_shape(
                functools.partial(opt_lib.init, opt_cfg), params_sds)
            m_specs = shr.moment_specs(p_specs, params_sds, mesh)
            o_specs = opt_lib.OptState(
                step=jax.sharding.PartitionSpec(),
                mu=m_specs, nu=m_specs,
                error=(None if opt_sds.error is None else p_specs))
            o_shardings = shr.to_named(mesh, o_specs)
            fn = jax.jit(
                build_train_step(cfg, opt_cfg, microbatches=microbatches),
                in_shardings=(p_shardings, o_shardings, b_shardings),
                donate_argnums=(0, 1))
            lowered = fn.lower(params_sds, opt_sds, batch_sds)
        elif kind == "prefill":
            fn = jax.jit(
                build_prefill(cfg, max_seq=sh["seq_len"]),
                in_shardings=(p_shardings, b_shardings))
            lowered = fn.lower(params_sds, batch_sds)
        else:  # decode
            caches_sds = cache_shapes(cfg, sh["global_batch"], sh["seq_len"])
            c_shardings = shr.to_named(
                mesh, shr.cache_specs(cfg, mesh, caches_sds))
            fn = jax.jit(
                build_decode_step(cfg),
                in_shardings=(p_shardings, c_shardings,
                              b_shardings["tokens"]),
                donate_argnums=(1,))
            lowered = fn.lower(params_sds, caches_sds, batch_sds["tokens"])

        compiled = lowered.compile()

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # some backends lack the query
        mem_d = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    upcast = cpu_dot_upcast_bytes(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok",
        "kind": kind,
        "devices": int(np.prod(list(mesh.shape.values()))),
        "seconds": round(time.time() - t0, 1),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "memory": {**mem_d, "cpu_dot_upcast_bytes": upcast},
        "collectives": coll,
        "remat": cfg.remat_policy,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--remat", default=None, choices=["full", "dots", "none"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--moment-dtype", default="float32")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multipod"]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
        out_path = os.path.join(args.out, tag + ".json")
        try:
            res = run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                           remat=args.remat, microbatches=args.microbatch,
                           opt_overrides={"moment_dtype": args.moment_dtype}
                           if args.moment_dtype != "float32" else None)
        except Exception as e:
            traceback.print_exc()
            res = {"arch": arch, "shape": shape,
                   "mesh": "multipod" if mp else "pod",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            extra = (f" flops={res['flops']:.3g}"
                     f" coll={res['collectives']['total_bytes']:.3g}B"
                     f" args={res['memory'].get('argument_bytes')}"
                     f" t={res['seconds']}s")
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    if failures:
        print(f"[dryrun] {failures} FAILURES", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
