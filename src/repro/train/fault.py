"""Fault tolerance & elasticity utilities.

At 1000+ nodes the failure model is: a host dies every few hours, slow
hosts (stragglers) are constant, and whole-pod preemptions happen.  The
mitigations implemented here (and where they live):

  * checkpoint/restart      — train/checkpoint.py (atomic, verified,
                              fallback-to-older); the loop in
                              launch/train.py saves every N steps and
                              auto-resumes from the newest valid step.
  * deterministic data      — data/pipeline.py keys batches by
                              (seed, step): restart replays nothing.
  * elastic re-mesh         — `elastic_mesh` below rebuilds the largest
                              usable (data, model) mesh from surviving
                              devices; params re-shard on restore because
                              checkpoints are sharding-agnostic numpy.
  * straggler mitigation    — SPED's walker estimates are valid for ANY
                              subset of walkers (unbiasedness is
                              per-walker; see core/walks.py), so the
                              natural policy is deadline-based: psum what
                              arrived, scale by the live fraction.
                              `straggler_scale` implements the reweight.
                              For LM training the equivalent is backup
                              workers + the synchronous update simply
                              proceeding on the quorum's pmean.
  * retry with backoff      — `retrying` wraps host-side steps (I/O,
                              compile) which are the usual flaky layer.
"""
from __future__ import annotations

import logging
import math
import time
from typing import Callable, Sequence

import jax
import numpy as np

log = logging.getLogger(__name__)


def elastic_mesh(devices: Sequence | None = None, model_axis: int = 16,
                 pod_size: int = 256):
    """Build the largest (pod, data, model) mesh from surviving devices.

    Keeps the model axis fixed (param sharding must divide) and absorbs
    losses into the data axis: losing hosts shrinks global batch, not the
    model.  Returns (mesh, dropped_devices)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    model = math.gcd(model_axis, n)
    usable_pods = max(1, n // pod_size)
    per_pod = (n // usable_pods // model) * model
    usable = usable_pods * per_pod
    dropped = devices[usable:]
    devs = np.array(devices[:usable]).reshape(
        usable_pods, per_pod // model, model)
    from jax.sharding import Mesh
    axes = ("pod", "data", "model")
    return Mesh(devs, axes), dropped


def straggler_scale(contributions_arrived: jax.Array,
                    total_workers: int) -> jax.Array:
    """Reweight a psum of partial (masked) contributions so the estimate
    stays unbiased when stragglers are dropped at the deadline:
    scale = total / arrived  (arrived > 0)."""
    import jax.numpy as jnp
    arrived = jnp.maximum(contributions_arrived, 1)
    return jnp.asarray(total_workers, jnp.float32) / arrived


def retrying(fn: Callable, attempts: int = 3, base_delay: float = 0.5,
             retry_on: tuple = (IOError, OSError, ValueError)):
    """Host-side retry wrapper with exponential backoff."""

    def wrapped(*args, **kwargs):
        for i in range(attempts):
            try:
                return fn(*args, **kwargs)
            except retry_on as e:
                if i == attempts - 1:
                    raise
                delay = base_delay * (2 ** i)
                log.warning("attempt %d/%d failed (%s); retrying in %.1fs",
                            i + 1, attempts, e, delay)
                time.sleep(delay)

    return wrapped


class HeartbeatMonitor:
    """Tracks per-host step timestamps; hosts silent past `timeout_s` are
    declared dead, triggering elastic_mesh + restore in the driver loop.
    (Host liveness transport — e.g. a KV store — is deployment-specific;
    this class encapsulates the policy so the driver stays simple.)"""

    def __init__(self, num_hosts: int, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self.last_seen = {h: time.time() for h in range(num_hosts)}

    def beat(self, host: int):
        self.last_seen[host] = time.time()

    def dead_hosts(self) -> list[int]:
        now = time.time()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout_s]
