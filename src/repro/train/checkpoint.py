"""Fault-tolerant checkpointing.

Guarantees:
  * ATOMIC: payload is written to a temp dir and os.rename'd into place —
    a crash mid-save never corrupts the latest checkpoint.
  * VERIFIED: every array file carries a sha256 in the manifest; restore
    validates before handing params to the trainer.
  * RESUMABLE: restore() returns the exact step + data-pipeline cursor, so
    a preempted job replays nothing and skips nothing (the synthetic
    pipeline is keyed by (seed, step) — see data/pipeline.py).
  * GC: keep_last N checkpoints are retained, older ones deleted only
    AFTER a newer one is durably in place.

Layout:  <dir>/step_000123/{manifest.json, arr_000.npy, ...}
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# numpy can't natively save/cast bf16 etc.; round-trip via a u16/u8 view
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, keep_last: int = 3,
         extra: dict | None = None) -> str:
    """Atomically persist `tree` (any pytree of arrays) at `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "extra": extra or {},
        "arrays": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        if dtype_name in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype_name][1])
        fname = f"arr_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        np.save(path, arr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["arrays"].append({
            "file": fname, "sha256": digest,
            "shape": list(arr.shape), "dtype": dtype_name,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, tree_like, step: int | None = None):
    """Restore into the structure of `tree_like`.  Returns (tree, extra).

    Raises on hash mismatch (corrupt checkpoint) — the caller's retry
    loop then falls back to the previous step directory.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(tree_like)
    if len(manifest["arrays"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['arrays'])} arrays, expected "
            f"{len(leaves_like)}")
    leaves = []
    for i, (meta, like) in enumerate(zip(manifest["arrays"], leaves_like)):
        fpath = os.path.join(path, meta["file"])
        with open(fpath, "rb") as f:
            raw = f.read()
        digest = hashlib.sha256(raw).hexdigest()
        if digest != meta["sha256"]:
            raise IOError(f"integrity failure in {fpath}")
        arr = np.load(fpath)
        if meta["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[meta["dtype"]][0])
        if list(arr.shape) != list(np.shape(like)):
            raise ValueError(
                f"array {i}: shape {arr.shape} != expected {np.shape(like)}")
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), manifest["extra"]


def restore_with_fallback(ckpt_dir: str, tree_like):
    """Try newest -> older checkpoints until one validates (survives a
    node dying mid-upload or bit-rot on one copy)."""
    if not os.path.isdir(ckpt_dir):
        raise FileNotFoundError(ckpt_dir)
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    last_err: Exception | None = None
    for s in steps:
        try:
            tree, extra = restore(ckpt_dir, tree_like, step=s)
            return tree, extra, s
        except (IOError, ValueError) as e:  # corrupt — try older
            last_err = e
    raise IOError(f"no valid checkpoint in {ckpt_dir}: {last_err}")
