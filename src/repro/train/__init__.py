"""Training substrate."""
