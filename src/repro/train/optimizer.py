"""AdamW optimizer with production trimmings, no external deps:

  * global-norm gradient clipping
  * cosine schedule with linear warmup
  * ZeRO-1: first/second moments sharded over the data axis (param
    shards stay whole; moments are what dominate optimizer HBM)
  * optional gradient COMPRESSION with error feedback (int8 quantization
    of the DP all-reduce payload; the residual is carried to the next
    step).  At 1000+ node scale the DP all-reduce is the binding
    cross-pod collective; 4x payload shrink is the classic mitigation.

Pure pytree functions; state is a pytree so the checkpoint manager and
pjit shard it like everything else.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.sharding import maybe_shard, resolve_spec


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False  # int8 + error feedback on the DP payload
    zero1: bool = True  # shard moments over "dp"
    # moment storage dtype: bf16 halves optimizer HBM (math stays f32);
    # the classic fit-lever for >100B models on small pods
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # first moments
    nu: Any  # second moments
    error: Any  # compression error-feedback residual (zeros if unused)


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def _moment_like(p, zero1: bool, dtype):
    z = jnp.zeros(p.shape, dtype)
    if zero1 and p.ndim >= 1:
        # shard the leading dim over the data axis where possible
        return maybe_shard(z, "dp")
    return z


def init(cfg: OptConfig, params) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    mu = jax.tree.map(lambda p: _moment_like(p, cfg.zero1, mdt), params)
    nu = jax.tree.map(lambda p: _moment_like(p, cfg.zero1, mdt), params)
    err = jax.tree.map(jnp.zeros_like, params) if cfg.compress_grads else None
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu, error=err)


def _quantize_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g, err):
    """Error-feedback int8 round trip: returns (g_hat, new_err) where
    g_hat is what the (compressed) all-reduce would deliver and new_err
    carries the quantization residual to the next step."""
    target = g + err
    q, scale = _quantize_int8(target)
    g_hat = q.astype(g.dtype) * scale
    return g_hat, target - g_hat


def apply(cfg: OptConfig, state: OptState, params, grads):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    if cfg.compress_grads:
        pairs = jax.tree.map(compress_decompress, grads, state.error)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state.error

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m.astype(mdt), v.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu,
                                error=new_err), \
        {"grad_norm": gnorm, "lr": lr}
