"""Data pipelines."""
