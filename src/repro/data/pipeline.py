"""Data pipelines.

Two streams feed the framework:

  * TOKEN stream for the LM pool — synthetic but DETERMINISTIC: batch at
    step t is a pure function of (seed, t), so resume-after-failure is a
    seek, not a replay, and every data-parallel shard slices its own rows
    (no host broadcast).  A real deployment swaps `token_batch` for a
    tokenized corpus reader with the same (seed, step) -> batch contract.

  * EDGE stream for SPED — uniform minibatches of incidence rows
    (paper Sec. 3's stochastic optimization model), same contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laplacian import EdgeList


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int):
        """Full global batch for `step` (dry-run / single host)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = jax.random.randint(
            key, (self.global_batch, self.seq_len), 0, self.vocab_size,
            dtype=jnp.int32)
        labels = jnp.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}

    def shard_batch_at(self, step: int, shard: int, num_shards: int):
        """Only this host's rows — identical values to slicing batch_at,
        without materializing the global batch (multi-host pattern)."""
        assert self.global_batch % num_shards == 0
        rows = self.global_batch // num_shards
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        # fold the shard id so each host draws only its slice, while the
        # (seed, step, shard) triple remains the deterministic address
        skey = jax.random.fold_in(key, shard)
        toks = jax.random.randint(
            skey, (rows, self.seq_len), 0, self.vocab_size, dtype=jnp.int32)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


@dataclasses.dataclass(frozen=True)
class EdgePipeline:
    """Uniform-with-replacement edge minibatches from a fixed graph."""
    graph: EdgeList
    batch_edges: int
    seed: int = 0

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        sel = jax.random.randint(key, (self.batch_edges,), 0,
                                 self.graph.num_edges)
        return {
            "src": self.graph.src[sel],
            "dst": self.graph.dst[sel],
            "weight": self.graph.weight[sel],
            "num_edges_total": self.graph.num_edges,
        }
