"""Spectral probing & dilation planning — the "stochastic" half of the
paper's title as a first-class subsystem.

The dilation transforms (core.series) only pay off when their free
parameters — family, degree, per-graph scale, reversal shift — match the
actual spectrum.  This package estimates that spectrum matrix-free with
a handful of matvecs and turns the estimate into a tuned plan:

Module map
----------
probes
    jit-compiled, MatVec-convention spectral probes: Lanczos with full
    reorthogonalization, stochastic Lanczos quadrature (tight
    ``lambda_max`` + coarse spectral-density histogram + trace),
    Girard-Hutchinson trace estimation (deterministic and minibatch
    operators), and a counting-function bottom-edge eigengap localizer.
    Node-padded operators (streaming capacity classes) probe as their
    unpadded selves via the ``n_real`` mask.
plan
    Host-side planner: ``plan_dilation(probe, k, budget)`` selects the
    transform family / degree / strength / reversal shift from the
    probed spectrum, snapped onto a coarse grid so probe noise maps to
    the same plan (and the compiled-program set stays small);
    ``series_from_plan`` materializes it via the core.series
    constructors.  The Gershgorin ``2*max_degree`` bound survives as cap
    and jit-time fallback.

Entry points: ``probe_and_plan(g, k)`` here,
``repro.core.operators.planned_operator`` for a ready solver operator,
``ClusteringConfig(transform="auto")`` for the full pipeline, and the
streaming service probes on admission and drift re-solves by default.
``benchmarks/bench_spectral.py`` measures probe cost vs solver
iterations saved against oracle and fixed-config tuning.
"""
from repro.spectral.plan import (  # noqa: F401
    TAU_GRID,
    DilationPlan,
    plan_dilation,
    probe_and_plan,
    series_from_plan,
    wanted_decay_cap,
)
from repro.spectral.probes import (  # noqa: F401
    ProbeResult,
    bottom_edge,
    eigenvalue_count,
    hutchinson_trace,
    lanczos,
    probe_edge_arrays,
    probe_from_eigenvalues,
    probe_graph,
    slq_probe,
    spectral_density,
)
