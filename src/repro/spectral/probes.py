"""Matrix-free spectral probes: SLQ, Hutchinson, and edge localizers.

Every dilation transform in this repo has free parameters — polynomial
degree, spectral-radius scale, reversal shift — whose right values are
functions of the SPECTRUM of the graph at hand.  This module estimates
that spectrum with a handful of matvecs, using the same ``MatVec``
convention as :mod:`repro.core.operators`, so the probes run unchanged
on dense, edge-list, capacity-padded, sharded, and minibatch operators.

Probes
------
``lanczos``
    m-step Lanczos with full (twice-is-enough classical Gram-Schmidt)
    reorthogonalization.  m is small (10-30), so the O(m n) per-step
    reorthogonalization is cheaper than losing orthogonality and
    duplicating Ritz values.  Breakdown (Krylov space exhausted, e.g.
    m >= n on tiny graphs) is guarded: the recurrence continues on zero
    vectors, which appends decoupled zero-weight blocks to the
    tridiagonal that quadrature then ignores.
``slq_probe``
    Stochastic Lanczos quadrature (Ubaru, Chen & Saad 2017): run
    ``num_probes`` independent Lanczos recurrences from random unit
    vectors; each tridiagonal's eigendecomposition yields Ritz nodes
    theta_j and weights tau_j^2 (squared first eigenvector components)
    — an n-point spectral measure compressed to m points.  From these we
    read off (1) a tight ``lambda_max`` estimate (top Ritz value plus
    its residual bound beta_m |e_m^T y|; Lanczos converges at the edges
    first, so a few steps suffice), (2) an unbiased trace estimate, and
    (3) a coarse spectral-density histogram (`spectral_density`).
``hutchinson_trace``
    Girard-Hutchinson trace estimator with Rademacher probes; works on
    both deterministic and keyed (stochastic minibatch) matvecs, and is
    unbiased for the minibatch operator because batch and probe draws
    are independent.
``bottom_edge``
    Cheap bottom-edge eigengap localizer: the SLQ weights estimate the
    eigenvalue COUNTING function N(t) ~ n * sum_{theta_j <= t} w_j
    (weights carry eigenspace multiplicity, so clustered bottom
    eigenvalues that Lanczos dedupes still count), and the k-th /
    (k+1)-th crossing points localize (lambda_k, lambda_{k+1}).

Node-padded operators (the streaming store's capacity classes) are
handled by ``n_real``: probe vectors are masked to the first ``n_real``
rows, and since no edge touches a padding node, the whole Krylov space
stays in the real subspace — the probe never sees the padding zeros.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laplacian import EdgeList, edge_matvec_arrays

MatVec = Callable[[jax.Array], jax.Array]

# Breakdown test is RELATIVE to the raw matvec norm: normalizing a
# residual that is pure round-off (||w|| ~ eps * ||L q||) would amplify
# its non-orthogonal round-off components by 1/||w|| and poison every
# later reorthogonalization, so such steps terminate the recurrence
# instead (the Krylov space is numerically invariant at that point).
_BREAKDOWN_REL = 1e-4
_TINY = 1e-30


class ProbeResult(NamedTuple):
    """Compressed spectral information from one SLQ run.

    All fields are arrays (jit-transparent); ``n`` is the REAL node
    count the quadrature is normalized to (a padded operator probes as
    its unpadded self).
    """

    ritz: jax.Array  # (num_probes, num_steps) Ritz nodes per probe
    weights: jax.Array  # (num_probes, num_steps) quadrature weights, rows sum to 1
    lambda_max: jax.Array  # () residual-corrected top-edge estimate
    trace: jax.Array  # () SLQ estimate of tr(L)
    n: jax.Array  # () float32 real node count
    num_matvecs: jax.Array  # () int32 probe cost in single-vector matvecs


def lanczos(matvec: MatVec, v0: jax.Array, num_steps: int
            ) -> tuple[jax.Array, jax.Array]:
    """m-step Lanczos with full reorthogonalization.

    Returns (alpha (m,), beta (m,)): the tridiagonal is
    diag(alpha) + offdiag(beta[:-1]); beta[-1] is the residual norm
    feeding the Ritz-value error bound.  v0 need not be normalized.

    Breakdown (graphs with few distinct eigenvalues exhaust the Krylov
    space in < m steps) is sticky: the recurrence continues on zero
    vectors, with zero alpha/beta, so the tridiagonal gains decoupled
    zero blocks whose quadrature weight is exactly zero.
    """
    n = v0.shape[0]
    dtype = v0.dtype
    q0 = v0 / jnp.maximum(jnp.linalg.norm(v0), _TINY)
    # num_steps + 1 rows: row m is scratch for the final next-vector write
    q_buf = jnp.zeros((num_steps + 1, n), dtype).at[0].set(q0)

    def body(i, carry):
        q, alpha, beta = carry
        w = matvec(q[i])
        raw_norm = jnp.linalg.norm(w)
        a = jnp.vdot(q[i], w)
        # Full reorthogonalization against every stored vector (rows > i
        # are zero, so no masking needed); twice kills the O(eps kappa)
        # residue of the first pass.
        w = w - q.T @ (q @ w)
        w = w - q.T @ (q @ w)
        b = jnp.linalg.norm(w)
        alive = b > _BREAKDOWN_REL * (raw_norm + _TINY)
        keep = jnp.where(alive, 1.0, 0.0)
        q_next = keep * w / jnp.maximum(b, _TINY)
        return (q.at[i + 1].set(q_next), alpha.at[i].set(a),
                beta.at[i].set(keep * b))

    _, alpha, beta = jax.lax.fori_loop(
        0, num_steps, body,
        (q_buf, jnp.zeros((num_steps,), dtype), jnp.zeros((num_steps,), dtype)))
    return alpha, beta


def _tridiag_eig(alpha: jax.Array, beta: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """(theta, U) of the m x m Lanczos tridiagonal (m is small)."""
    m = alpha.shape[0]
    t = jnp.diag(alpha)
    if m > 1:
        t = t + jnp.diag(beta[:-1], 1) + jnp.diag(beta[:-1], -1)
    return jnp.linalg.eigh(t)


def slq_probe(
    matvec: MatVec,
    n: int,
    key: jax.Array,
    *,
    num_probes: int = 4,
    num_steps: int = 24,
    n_real: jax.Array | int | None = None,
) -> ProbeResult:
    """Stochastic Lanczos quadrature of the operator's spectrum.

    Fully traceable: wrap in jit at the call site (see ``probe_graph``
    and the streaming service) so shapes — not values — decide
    compilation.  ``n_real`` masks probe vectors for node-padded
    operators and may be a traced scalar.
    """
    n_real_f = jnp.asarray(n if n_real is None else n_real, jnp.float32)
    mask = (jnp.arange(n, dtype=jnp.float32) <
            n_real_f) if n_real is not None else None

    def one(k: jax.Array):
        v0 = jax.random.normal(k, (n,), jnp.float32)
        if mask is not None:
            v0 = v0 * mask
        alpha, beta = lanczos(matvec, v0, num_steps)
        theta, u = _tridiag_eig(alpha, beta)
        w = u[0, :] ** 2  # quadrature weights; sums to 1
        # Ritz residual ||L y - theta y|| = beta_m |e_m^T u| per pair
        resid = beta[-1] * jnp.abs(u[-1, :])
        return theta, w, jnp.max(theta + resid)

    theta, weights, lam_ub = jax.vmap(one)(jax.random.split(key, num_probes))
    trace = n_real_f * jnp.mean(jnp.sum(weights * theta, axis=1))
    return ProbeResult(
        ritz=theta,
        weights=weights,
        lambda_max=jnp.max(lam_ub),
        trace=trace,
        n=n_real_f,
        num_matvecs=jnp.asarray(num_probes * num_steps, jnp.int32),
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_nodes", "num_probes", "num_steps", "backend"))
def probe_edge_arrays(
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    key: jax.Array,
    n_real: jax.Array,
    *,
    num_nodes: int,
    num_probes: int = 4,
    num_steps: int = 24,
    backend: str = "segment",
) -> ProbeResult:
    """Jitted SLQ over bare (possibly capacity-padded) edge buffers.

    One compile per (edge capacity, node capacity, probe config,
    backend) — the streaming service's capacity classes hit this cache,
    so probing a newly admitted session recompiles nothing.

    ``backend`` routes the probe matvec through repro.core.backend so
    the spectrum estimate exercises the same kernels the solve will.
    Blockings cannot be built under trace, so the pallas path uses the
    one-hot kernel and silently stays on segment past its n limit.
    """
    from repro.core import backend as backend_mod

    matvec = backend_mod.edge_arrays_matvec_fn(src, dst, weight, backend,
                                               num_nodes=num_nodes)
    return slq_probe(
        matvec, num_nodes, key,
        num_probes=num_probes, num_steps=num_steps, n_real=n_real)


@functools.lru_cache(maxsize=64)
def _sharded_probe_program(mesh, edge_axes: tuple, num_nodes: int,
                           num_probes: int, num_steps: int, backend: str):
    """Compiled sharded-SLQ program, cached per (mesh, shapes, config).

    ONE shard_mapped program wraps the whole quadrature: probe vectors
    are vmapped inside on replicated panels and every Lanczos matvec is
    a per-shard kernel followed by one psum over the edge axes — the
    probe distributes exactly like the solve it tunes.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import backend as backend_mod

    b = backend_mod.resolve_for_arrays(backend, num_nodes)
    interp = backend_mod.kernel_interpret()
    spec_e = P(edge_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, P(), P()),
        out_specs=P(),
        check_vma=False)  # Lanczos scan carries mixed-replication values
    def probe(src, dst, weight, key, n_real):
        local = backend_mod.edge_arrays_matvec_fn(
            src, dst, weight, b, num_nodes=num_nodes, interpret=interp)

        def mv(v):
            return jax.lax.psum(local(v), edge_axes)

        return slq_probe(mv, num_nodes, key,
                         num_probes=num_probes, num_steps=num_steps,
                         n_real=n_real)

    return jax.jit(probe)


def probe_sharded_edge_arrays(
    mesh,
    src: jax.Array,
    dst: jax.Array,
    weight: jax.Array,
    key: jax.Array,
    n_real: jax.Array,
    *,
    num_nodes: int,
    edge_axes=("data",),
    num_probes: int = 4,
    num_steps: int = 24,
    backend: str = "segment",
) -> ProbeResult:
    """SLQ over MESH-SHARDED edge buffers (stream.sharded's probe path).

    Semantically identical to :func:`probe_edge_arrays` — same Lanczos
    recurrence, same keys, the matvec is just psum-assembled from edge
    shards — so the streaming service's dilation anchors match between
    sharded and single-device serving up to collective summation order.
    The edge buffer's length must divide evenly by the mesh's edge-axis
    shard count (the store's balanced capacity invariant).
    """
    program = _sharded_probe_program(
        mesh, tuple(edge_axes), num_nodes, num_probes, num_steps, backend)
    return program(src, dst, weight, key, n_real)


@functools.lru_cache(maxsize=64)
def _model_probe_program(mesh, model_axes: tuple, block_n: int,
                         block_e: int, num_chunks: int, num_nodes: int,
                         num_shards: int, rows: int,
                         num_probes: int, num_steps: int, backend: str):
    """Compiled PANEL-sharded SLQ program, cached per (mesh, layout
    statics, config).

    The matvec decomposes by node ownership instead of by edge slice:
    each shard computes its OWNED rows of ``L v`` from its
    destination-aligned chunk layout (``model_local_rows`` — the same
    row computation the model-sharded tick runs) and one psum assembles
    the disjoint row ranges.  No shard ever materializes another
    shard's edges.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import backend as backend_mod
    from repro.kernels.edge_spmm import ops as es_ops

    use_kernel = backend_mod.resolve_backend(backend) == "pallas"
    interp = backend_mod.kernel_interpret()
    n_pad = num_shards * rows
    spec_b = P(model_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_b,) * 5 + (P(), P()),
        out_specs=P(),
        check_vma=False)  # Lanczos scan carries mixed-replication values
    def probe(u_local, other, weight, chunk_block, deg, key, n_real):
        sidx = jnp.zeros((), jnp.int32)
        for a in model_axes:
            sidx = sidx * mesh.shape[a] + jax.lax.axis_index(a)
        row_start = sidx * rows
        ab = jnp.asarray([1.0, 0.0], jnp.float32)  # plain L v

        def mv(v):
            owned = es_ops.model_local_rows(
                u_local[0], other[0], weight[0], chunk_block[0], deg[0],
                v[:, None], ab, row_start,
                block_n=block_n, block_e=block_e, num_chunks=num_chunks,
                padded_nodes=n_pad, use_kernel=use_kernel,
                interpret=interp)
            z = jnp.zeros((n_pad, 1), jnp.float32)
            full = jax.lax.psum(
                jax.lax.dynamic_update_slice(z, owned, (row_start, 0)),
                model_axes)
            return full[:num_nodes, 0]

        return slq_probe(mv, num_nodes, key,
                         num_probes=num_probes, num_steps=num_steps,
                         n_real=n_real)

    return jax.jit(probe)


def probe_model_sharded(
    mesh,
    blocking,
    key: jax.Array,
    n_real: jax.Array,
    *,
    model_axes=("model",),
    num_probes: int = 4,
    num_steps: int = 24,
    backend: str = "segment",
) -> ProbeResult:
    """SLQ over a PANEL-sharded layout (the model-serving probe path).

    ``blocking`` is a :class:`~repro.kernels.edge_spmm.ops
    .ModelShardedBlocking`; the quadrature is semantically identical to
    :func:`probe_edge_arrays` — same Lanczos recurrence, same keys —
    with the matvec psum-assembled from each shard's owned rows, so the
    dilation anchors match replicated serving up to summation order.
    """
    program = _model_probe_program(
        mesh, tuple(model_axes), blocking.block_n, blocking.block_e,
        blocking.num_chunks, blocking.num_nodes, blocking.num_shards,
        blocking.rows_per_shard, num_probes, num_steps, backend)
    return program(blocking.u_local, blocking.other, blocking.weight,
                   blocking.chunk_block, blocking.deg, key, n_real)


def probe_graph(
    g: EdgeList,
    key: jax.Array | None = None,
    num_probes: int = 4,
    num_steps: int = 24,
    backend: str = "segment",
) -> ProbeResult:
    """Host convenience: SLQ-probe an EdgeList's Laplacian spectrum."""
    if key is None:
        key = jax.random.PRNGKey(0)
    num_steps = min(num_steps, g.num_nodes)
    return probe_edge_arrays(
        g.src, g.dst, g.weight, key,
        jnp.asarray(g.num_nodes, jnp.int32),
        num_nodes=g.num_nodes, num_probes=num_probes, num_steps=num_steps,
        backend=backend)


def probe_from_eigenvalues(lam) -> ProbeResult:
    """Exact ProbeResult from a full spectrum — the oracle the planner
    benchmarks calibrate against (same planner, perfect probe)."""
    lam = jnp.sort(jnp.asarray(lam, jnp.float32).ravel())
    n = lam.shape[0]
    w = jnp.full((1, n), 1.0 / n, jnp.float32)
    return ProbeResult(
        ritz=lam[None, :],
        weights=w,
        lambda_max=lam[-1],
        trace=jnp.sum(lam),
        n=jnp.asarray(n, jnp.float32),
        num_matvecs=jnp.asarray(0, jnp.int32),
    )


def hutchinson_trace(
    matvec,
    n: int,
    key: jax.Array,
    *,
    num_probes: int = 16,
    keyed: bool = False,
    n_real: jax.Array | int | None = None,
) -> jax.Array:
    """Girard-Hutchinson trace estimate with Rademacher probes.

    ``keyed=True`` treats ``matvec`` as a stochastic op(key, v) — e.g.
    the minibatch Laplacian — and gives each probe an independent batch
    key, keeping the estimator unbiased for E_batch[op] (probe and batch
    draws are independent, and each enters the quadratic form linearly).
    """
    mask = (jnp.arange(n, dtype=jnp.float32) <
            jnp.asarray(n_real, jnp.float32)) if n_real is not None else None

    def one(k: jax.Array) -> jax.Array:
        zk, bk = jax.random.split(k)
        z = jax.random.rademacher(zk, (n,), jnp.float32)
        if mask is not None:
            z = z * mask
        az = matvec(bk, z) if keyed else matvec(z)
        return jnp.vdot(z, az)

    return jnp.mean(jax.vmap(one)(jax.random.split(key, num_probes)))


# ---------------------------------------------------------------------------
# Host-side readouts (feed the planner, which returns static jit args).
# ---------------------------------------------------------------------------

def _counting_points(probe: ProbeResult) -> tuple[np.ndarray, np.ndarray]:
    """Pooled (sorted ritz nodes, cumulative eigenvalue counts)."""
    theta = np.asarray(probe.ritz, np.float64).ravel()
    num_probes = probe.ritz.shape[0]
    count = np.asarray(probe.weights, np.float64).ravel() \
        * float(probe.n) / num_probes
    order = np.argsort(theta)
    return theta[order], np.cumsum(count[order])


def eigenvalue_count(probe: ProbeResult, t: float) -> float:
    """Estimated #{lambda_i <= t} from the SLQ measure."""
    theta, cum = _counting_points(probe)
    idx = np.searchsorted(theta, t, side="right")
    return float(cum[idx - 1]) if idx > 0 else 0.0


def _crossing(theta: np.ndarray, cum: np.ndarray, level: float) -> float:
    return float(theta[min(np.searchsorted(cum, level), len(theta) - 1)])


def bottom_edge(probe: ProbeResult, k: int) -> tuple[float, float]:
    """Coarse (lambda_k, lambda_{k+1}) localizer (1-indexed, ascending).

    Scans the estimated eigenvalue counting function
    N(t) ~ n * sum_{theta_j <= t} w_j for the WIDEST gap between pooled
    Ritz nodes whose below-count is plausibly k (within max(1, k/2) —
    per-probe cluster weights fluctuate at Chi^2 scale, so exact
    crossings of k are coin flips on degenerate spectra, while a
    macroscopic gap survives any plausible count).  Weights carry
    eigenspace multiplicity, so a cluster of near-equal bottom
    eigenvalues that Lanczos collapses to one Ritz node still
    contributes its full count.  Falls back to the plain k-th/(k+1)-th
    crossings when no gap has a plausible count (gapless bottom edge).
    Coarse by construction — the planner consumes it through a snapped
    decision grid, so small probe noise maps to the same plan.
    """
    theta, cum = _counting_points(probe)
    tol = max(1.0, 0.5 * k)
    best_width = -1.0
    best = None
    for i in range(len(theta) - 1):
        if abs(cum[i] - k) <= tol:
            width = theta[i + 1] - theta[i]
            if width > best_width:
                best_width = width
                best = (theta[i], theta[i + 1])
    if best is None:
        best = (_crossing(theta, cum, k - 0.5), _crossing(theta, cum, k + 0.5))
    lam_k, lam_k1 = best
    lam_k = max(float(lam_k), 0.0)
    return lam_k, max(float(lam_k1), lam_k)


def spectral_density(
    probe: ProbeResult,
    num_bins: int = 32,
    lo: float = 0.0,
    hi: float | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Coarse spectral-density histogram: (bin_edges (B+1,), mass (B,)).

    ``mass`` estimates eigenvalue counts per bin and sums to ~n (Ritz
    nodes outside [lo, hi] are clipped into the boundary bins so no mass
    is silently dropped).
    """
    if hi is None:
        hi = float(probe.lambda_max)
    hi = max(hi, lo + 1e-12)
    theta = np.asarray(probe.ritz, np.float64).ravel()
    num_probes = probe.ritz.shape[0]
    count = np.asarray(probe.weights, np.float64).ravel() \
        * float(probe.n) / num_probes
    edges = np.linspace(lo, hi, num_bins + 1)
    mass, _ = np.histogram(np.clip(theta, lo, hi), bins=edges, weights=count)
    return edges, mass
