"""Dilation planner: probed spectrum -> tuned transform configuration.

Every call site used to hand-pick the transform family, polynomial
degree, and dilation strength, and to scale by the Gershgorin-style
bound ``2 * max_degree`` — which over-estimates ``lambda_max`` by ~2x on
dense/clique-like graphs and silently HALVES the effective dilation.
``plan_dilation`` replaces those guesses with a closed-form decision on
top of :class:`repro.spectral.probes.ProbeResult`:

* ``rho``: the SLQ ``lambda_max`` estimate, capped by the Gershgorin
  bound when provided (``rho_fallback`` — also the jit-time fallback
  when probing is disabled or returns garbage).
* relative bottom gap ``gamma = (lambda_{k+1} - lambda_k) / rho`` from
  the counting-function localizer.
* strength ``tau`` (the transform acts like ``-exp(-tau * lam / rho)``):
  chosen so the transformed gap ratio reaches ``exp(TARGET_LOG_GAP)``,
  i.e. ``tau ~ TARGET_LOG_GAP / gamma``, snapped UP onto ``TAU_GRID``.
  Snapping makes the plan robust (probe noise maps to the same plan) and
  keeps the set of distinct compiled operator programs small.
* degree: smallest odd value with ``degree >= DEGREE_PER_TAU * tau``,
  which keeps the limit series' per-matvec factor ``1 - tau*lam/(rho*l)``
  inside (-1, 1] on the probed range — no spectrum folding, bounded
  iterates — with margin for a slightly low ``rho`` estimate.
* family: ``identity`` when the raw gap is already wide (dilation buys
  nothing — the paper's well-separated regime); ``limit_neg_exp``
  (paper Table 2, monotone on all of R) when the degree fits the
  budget; ``cheb_neg_exp`` (beyond-paper Chebyshev fit of the same map,
  ~2x lower degree for equal accuracy) when it doesn't.

The planner is deliberately HOST-side: its outputs (family, degree) are
static jit arguments, so planning happens once per graph admission /
re-solve, never inside a compiled region.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import series as series_mod
from repro.spectral import probes as probes_mod

# Snapped dilation strengths.  8.0 is the repo's long-standing default;
# the grid brackets it both ways.
TAU_GRID = (2.0, 4.0, 8.0, 16.0, 24.0, 32.0)
# Aim for a transformed gap ratio of e^3 ~ 20 between the wanted and the
# first unwanted eigenvalue of the reversed operator.
TARGET_LOG_GAP = 3.0
# ...but never decay the WANTED spread below ~exp(-1.5): tau * lambda_k
# / rho <= MAX_WANTED_DECAY.  The trailing panel direction's relative
# convergence signal goes like exp(-tau * lambda_k / rho) (lambda_1 = 0
# on a Laplacian, so lambda_k IS the wanted spread); past ~1.5 the
# over-dilation pathology sets in — a huge tau separates lambda_k from
# lambda_{k+1} beautifully while starving the solver of signal for the
# wanted directions themselves.
MAX_WANTED_DECAY = 1.5
# Raw relative gap above which no transform is needed at all.
GAMMA_IDENTITY = 0.3
# degree >= DEGREE_PER_TAU * tau keeps |1 - tau*lam/(rho*degree)| <= 1
# on lam in [0, rho] with 25% margin for rho underestimation.
DEGREE_PER_TAU = 1.25
MIN_DEGREE = 7
# Chebyshev reaches the same -exp(-tau x) accuracy at roughly half the
# limit-series degree (coefficients decay like Bessel I_j(tau/2)).
CHEB_DEGREE_PER_TAU = 0.6
CHEB_DEGREE_PAD = 6
# Chebyshev fit interval stretches past rho so a slightly low estimate
# cannot put true eigenvalues outside the interpolation range (where a
# Chebyshev polynomial explodes and can fold the spectrum).
CHEB_RHO_MARGIN = 1.05


@dataclasses.dataclass(frozen=True)
class DilationPlan:
    """A fully determined dilation: feed to ``series_from_plan``.

    ``family``/``degree`` are static (compile-relevant); ``tau``/``rho``
    are the per-graph scale the series closes over.  ``source`` records
    how rho was obtained ("slq", "oracle", "fallback").
    """

    family: str  # "identity" | "limit_neg_exp" | "cheb_neg_exp"
    degree: int
    tau: float  # dimensionless strength: map ~ -exp(-tau * lam / rho)
    rho: float  # spectral-radius estimate the scale is anchored to
    lambda_star: float  # Eq. (8) reversal shift
    gamma: float  # probed relative bottom gap (lam_{k+1}-lam_k)/rho
    lam_k: float
    lam_k1: float
    probe_matvecs: int  # single-vector matvecs spent probing
    source: str = "slq"

    @property
    def predicted_gap_ratio(self) -> float:
        """Transformed (lam'_k / lam'_{k+1}) ratio the plan aims for."""
        return float(math.exp(min(self.tau * self.gamma, 60.0)))

    @property
    def scale(self) -> float:
        """`scale` argument for the limit series: maps lam -> tau*lam/rho."""
        return self.tau / max(self.rho, 1e-30)

    @property
    def operator_scale(self) -> float:
        """Magnitude of the reversed operator's top eigenvalue.

        ~1 for the exp-family series (values in (0, 1]); lambda_star for
        the reversed identity (values up to ~rho).  Solver step sizes
        tuned for a unit-scale operator should be divided by this — see
        ``suggested_lr``.
        """
        if self.family == "identity":
            return max(self.lambda_star, 1e-30)
        return 1.0

    def suggested_lr(self, base_lr: float = 0.4) -> float:
        """Step size normalized to the planned operator's scale (mu-EG /
        Oja steps are not scale-invariant: an identity plan on a graph
        with rho ~ 40 needs a ~40x smaller lr than a unit-scale series)."""
        return base_lr / self.operator_scale


def _next_odd(x: float) -> int:
    d = int(math.ceil(x))
    return d if d % 2 == 1 else d + 1


def identity_lambda_star(rho: float) -> float:
    """Eq. (8) reversal shift for the identity family: just above the
    spectral-radius estimate.  THE single definition — the streaming
    service's ordinary-batch rho rescale moves a session's shift with
    this same rule, so the update path and a fresh re-plan agree."""
    return rho * 1.01 + 1e-6


def wanted_decay_cap(lam_k: float, rho: float) -> float:
    """Largest tau keeping tau * lambda_k / rho <= MAX_WANTED_DECAY.

    The single definition of the over-dilation guard, shared by
    ``plan_dilation`` and the streaming service's per-session re-plan.
    """
    lam_k = min(max(lam_k, 0.0), rho)
    return MAX_WANTED_DECAY / max(lam_k / max(rho, 1e-30), 1e-3)


FAMILIES = ("identity", "limit_neg_exp", "cheb_neg_exp")


def plan_dilation(
    probe: probes_mod.ProbeResult | None,
    k: int,
    budget: int = 96,
    rho_fallback: float | None = None,
    source: str = "slq",
    lam_k: float | None = None,
    lam_k1: float | None = None,
    rho: float | None = None,
    tau_cap: float | None = None,
    families: tuple = FAMILIES,
) -> DilationPlan:
    """Select (family, degree, tau, rho, lambda_star) from a probe.

    ``budget`` caps the matvecs one operator application may spend (the
    series degree).  ``rho_fallback`` is the Gershgorin-style bound: it
    caps the probed radius (the bound is certain, the probe is not) and
    carries the plan alone when ``probe`` is None or non-finite —
    callers inside jit-sensitive paths keep working with probing off.
    Explicit ``lam_k``/``lam_k1``/``rho`` override the probe's
    bottom-edge localizer and ``lambda_max`` for callers that carry
    their own estimates (the streaming service re-plans from cached
    probe anchors without re-probing).  ``tau_cap`` bounds the strength
    like the wanted-decay cap (a configured ``dilation_strength``
    ceiling); ``families`` restricts the transform families a caller's
    compiled program set can execute — the streaming tick programs only
    evaluate the ``(I - c L)^degree`` form, so they exclude
    ``cheb_neg_exp`` and the planner weakens tau into the budget
    instead.

    Monotone by construction: for fixed lambda_k and rho, a larger
    probed bottom gap never yields a larger degree (wider gaps need
    less dilation; tau_needed falls with gamma while the wanted-decay
    cap stays put).
    """
    if budget < 1:
        raise ValueError(f"budget {budget} < 1 matvec")
    probe_matvecs = 0
    if probe is not None:
        probe_matvecs = int(probe.num_matvecs)
    if rho is not None:
        rho = float(rho)
    elif probe is not None:
        rho = float(probe.lambda_max)
    else:
        rho = float("nan")
    if rho_fallback is not None:
        rho = min(rho, float(rho_fallback)) if math.isfinite(rho) \
            else float(rho_fallback)
    if not math.isfinite(rho) or rho <= 0.0:
        # degenerate graph (no edges) or no spectral information at all:
        # identity transform, unit shift — nothing to dilate.
        return DilationPlan(
            family="identity", degree=1, tau=0.0, rho=max(rho, 0.0),
            lambda_star=1.0, gamma=1.0, lam_k=0.0, lam_k1=0.0,
            probe_matvecs=probe_matvecs, source="fallback")
    if lam_k is None or lam_k1 is None:
        if probe is not None:
            lam_k, lam_k1 = probes_mod.bottom_edge(probe, k)
        else:
            lam_k = lam_k1 = 0.0  # unknown gap: assume the hard case
            source = "fallback"
    lam_k = min(max(float(lam_k), 0.0), rho)
    lam_k1 = min(max(float(lam_k1), lam_k), rho)
    gamma = (lam_k1 - lam_k) / rho

    if gamma >= GAMMA_IDENTITY and "identity" in families:
        # Raw spectrum is already well separated at k; the reversed
        # identity (lambda* just above rho, Eq. 8) converges fine and
        # costs ONE matvec per application.
        return DilationPlan(
            family="identity", degree=1, tau=0.0, rho=rho,
            lambda_star=identity_lambda_star(rho), gamma=gamma,
            lam_k=lam_k, lam_k1=lam_k1,
            probe_matvecs=probe_matvecs, source=source)

    tau_needed = TARGET_LOG_GAP / max(gamma, 1e-3)
    tau = next((t for t in TAU_GRID if t >= tau_needed), TAU_GRID[-1])
    # Cap: keep the wanted eigenvalues alive (see MAX_WANTED_DECAY),
    # intersected with any caller-configured strength ceiling.
    # Snapped DOWN so the cap wins conflicts; lam_k <= rho guarantees
    # the wanted-decay cap is >= MAX_WANTED_DECAY, which the grid floor
    # covers.
    cap = wanted_decay_cap(lam_k, rho)
    if tau_cap is not None:
        cap = min(cap, float(tau_cap))
    if tau > cap:
        below = [t for t in TAU_GRID if t <= cap]
        tau = below[-1] if below else TAU_GRID[0]
    degree = max(_next_odd(DEGREE_PER_TAU * tau), MIN_DEGREE)
    family = "limit_neg_exp"
    if degree > budget:
        # The safe limit-series degree does not fit: first try the
        # Chebyshev fit of the same map (lower degree, same accuracy)...
        cheb_degree = _next_odd(CHEB_DEGREE_PER_TAU * tau + CHEB_DEGREE_PAD)
        if cheb_degree <= budget and "cheb_neg_exp" in families:
            return DilationPlan(
                family="cheb_neg_exp", degree=cheb_degree, tau=tau, rho=rho,
                lambda_star=0.0, gamma=gamma, lam_k=lam_k, lam_k1=lam_k1,
                probe_matvecs=probe_matvecs, source=source)
        # ...then weaken tau to the strongest grid value the budget can
        # evaluate safely (still monotone: smaller gap never gets MORE
        # degree than the budget).
        affordable = [t for t in TAU_GRID
                      if max(_next_odd(DEGREE_PER_TAU * t), MIN_DEGREE)
                      <= budget]
        if affordable:
            tau = affordable[-1]
            degree = max(_next_odd(DEGREE_PER_TAU * tau), MIN_DEGREE)
        else:
            # budget below even MIN_DEGREE: largest odd degree that fits,
            # strength scaled to what that degree evaluates safely
            degree = max(budget if budget % 2 == 1 else budget - 1, 1)
            tau = degree / DEGREE_PER_TAU
    return DilationPlan(
        family=family, degree=degree, tau=tau, rho=rho,
        lambda_star=0.0, gamma=gamma, lam_k=lam_k, lam_k1=lam_k1,
        probe_matvecs=probe_matvecs, source=source)


def series_from_plan(plan: DilationPlan) -> series_mod.SpectralSeries:
    """Materialize the plan as a SpectralSeries (core.series constructors)."""
    if plan.family == "identity":
        return series_mod.with_lambda_star(
            series_mod.identity_series(), plan.lambda_star)
    if plan.family == "limit_neg_exp":
        return series_mod.limit_neg_exp(plan.degree, scale=plan.scale)
    if plan.family == "cheb_neg_exp":
        return series_mod.cheb_neg_exp(
            plan.degree, rho=plan.rho * CHEB_RHO_MARGIN,
            tau=plan.tau / max(plan.rho, 1e-30))
    raise ValueError(f"unknown plan family {plan.family!r}")


def probe_and_plan(
    g,
    k: int,
    key=None,
    budget: int = 96,
    num_probes: int = 4,
    num_steps: int = 24,
    backend: str = "auto",
) -> tuple[probes_mod.ProbeResult, DilationPlan]:
    """One-call convenience: SLQ-probe an EdgeList, then plan.

    The Gershgorin bound rides along as the cap/fallback, so the result
    is never worse-anchored than the pre-planner call sites were.
    ``backend`` selects the probe matvec kernels (repro.core.backend),
    so probing runs on the same backend as the solve it tunes.
    """
    from repro.core import laplacian as lap

    probe = probes_mod.probe_graph(
        g, key=key, num_probes=num_probes, num_steps=num_steps,
        backend=backend)
    plan = plan_dilation(
        probe, k=k, budget=budget,
        rho_fallback=float(lap.spectral_radius_upper_bound(g)))
    return probe, plan
