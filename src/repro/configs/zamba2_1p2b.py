"""zamba2-1.2b [hybrid] — Mamba2 blocks + one weight-SHARED attention
block applied every 6 layers.  [arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
    sub_quadratic=True,  # SSM backbone => long_500k applicable
)
