"""Registry of the 10 assigned architectures (+ reduced smoke variants).

Exact dimensions from the assignment block; sources noted per entry.
Selectable via --arch <id> in launch/ and benchmarks/.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig

from repro.configs.qwen15_32b import CONFIG as _qwen15_32b
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.starcoder2_15b import CONFIG as _starcoder2_15b
from repro.configs.minitron_8b import CONFIG as _minitron_8b
from repro.configs.whisper_small import CONFIG as _whisper_small
from repro.configs.zamba2_1p2b import CONFIG as _zamba2_1p2b
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek_v2_236b
from repro.configs.granite_moe_1b import CONFIG as _granite_moe_1b
from repro.configs.mamba2_2p7b import CONFIG as _mamba2_2p7b
from repro.configs.llava_next_mistral_7b import CONFIG as _llava

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        _qwen15_32b, _qwen3_4b, _starcoder2_15b, _minitron_8b,
        _whisper_small, _zamba2_1p2b, _deepseek_v2_236b, _granite_moe_1b,
        _mamba2_2p7b, _llava,
    ]
}

# Input-shape set shared by the LM pool (assignment block).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def shape_applicable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (full attention at 524k is
    not deployable — skip noted in DESIGN.md Sec. 4)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small layers/width/experts/vocab, runs a
    forward/train step on CPU."""
    repl: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
    )
    if cfg.num_heads:
        repl["num_heads"] = 4
        repl["num_kv_heads"] = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads \
            < cfg.num_heads else 4
    if cfg.family == "moe":
        # capacity_factor 4.0 makes the smoke capacity non-binding (worst
        # case: every token routes its top-k to one expert), so the
        # prefill==decode round-trip tests compare the same computation;
        # production capacity behavior is exercised by the dry-run.
        repl.update(num_experts=8, moe_top_k=2, moe_d_ff=64,
                    num_shared_experts=min(cfg.num_shared_experts, 1),
                    capacity_factor=4.0)
    if cfg.use_mla:
        repl.update(kv_lora_rank=32, qk_rope_head_dim=16, v_head_dim=32)
    if cfg.ssm_state:
        repl.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        repl.update(num_layers=5, attn_every=3)
    if cfg.family == "encdec":
        repl.update(encoder_layers=2, encoder_seq=16)
    if cfg.family == "vlm":
        repl.update(num_patch_tokens=8)
    return dataclasses.replace(cfg, **repl)
