"""llava-next-mistral-7b [vlm] — Mistral-7B backbone; anyres tiling
frontend STUBBED (input_specs provides 576 patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    num_patch_tokens=576,
)
