"""whisper-small [audio] — enc-dec; conv frontend STUBBED (input_specs
provides 1500 precomputed frame embeddings).  [arXiv:2212.04356;
unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_seq=1500,
    gated_mlp=False,
)
