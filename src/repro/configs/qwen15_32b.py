"""qwen1.5-32b [dense] — QKV bias, near-MHA GQA.  [hf:Qwen/Qwen1.5-0.5B
family scaled per assignment; hf-verified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    # 64L near-MHA cache at 128 x 32k decode is the pool's largest KV
    # footprint: int8 cache (per-vector scales) keeps it on-chip (see
    # EXPERIMENTS.md #Dry-run memory table)
    kv_cache_dtype="int8",
)
