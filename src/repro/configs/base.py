"""Architecture config schema for the assigned-architecture pool.

One frozen dataclass describes every family (dense / moe / ssm / hybrid /
encdec-audio / vlm); family-specific fields default to "off".  Exact
dimension values live in the per-arch files of this package.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int  # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    capacity_factor: float = 1.25
    # dense d_ff is used for shared experts / first dense layers if any
    moe_first_dense_layers: int = 0

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    v_head_dim: int = 0  # 0 -> head_dim

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style): one weight-SHARED attention block applied
    # every `attn_every` layers, interleaved with SSM blocks
    attn_every: int = 0

    # encoder-decoder (whisper): encoder consumes stub frame embeddings
    encoder_layers: int = 0
    encoder_seq: int = 0  # e.g. 1500 mel frames

    # vlm stub frontend: first `num_patch_tokens` positions are replaced
    # by precomputed patch embeddings from input_specs()
    num_patch_tokens: int = 0

    # serving
    kv_cache_dtype: str = "bfloat16"  # or "int8" for memory-tight decode

    # does the arch support O(seq) long-context decode? (SSM/hybrid yes)
    sub_quadratic: bool = False

    # MLP style: SwiGLU (gated, 3 mats) vs classic 2-mat GELU MLP
    gated_mlp: bool = True

    # activation checkpointing: "full" (recompute everything, min memory),
    # "dots" (save matmul outputs, recompute elementwise only — removes
    # the remat re-forward, compute factor 8/6 -> 6/6), "none"
    remat_policy: str = "full" 

    # norm
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.use_mla and not self.v_head_dim:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder_cache(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), used for the
        roofline MODEL_FLOPS = 6 N D term."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # unembed
        L = self.num_layers
        if self.family in ("dense", "moe", "vlm"):
            n += L * self._attn_params()
            if self.family == "moe":
                n += L * (self.num_experts * 3 * d * self.moe_d_ff
                          + self.num_shared_experts * 3 * d * self.moe_d_ff
                          + d * self.num_experts)
            else:
                mats = 3 if self.gated_mlp else 2
                n += L * mats * d * self.d_ff
        elif self.family == "ssm":
            n += L * self._ssm_params()
        elif self.family == "hybrid":
            n_attn_blocks = 1  # weight-shared
            n += L * self._ssm_params() + n_attn_blocks * (
                self._attn_params() + 3 * d * self.d_ff)
        elif self.family == "encdec":
            mats = 3 if self.gated_mlp else 2
            n += self.encoder_layers * (self._attn_params() + mats * d * self.d_ff)
            n += L * (2 * self._attn_params() + mats * d * self.d_ff)
        n += L * 2 * d  # norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        L = self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        n += L * self._attn_params()
        n += L * (self.moe_top_k + self.num_shared_experts) * 3 * d * self.moe_d_ff
        n += L * d * self.num_experts  # router
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            hd = self.head_dim  # nope dim per head
            rd = self.qk_rope_head_dim
            r = self.kv_lora_rank
            return (d * self.num_heads * (hd + rd)  # q proj
                    + d * (r + rd)  # kv down + k_rope
                    + r * self.num_heads * (hd + self.v_head_dim)  # kv up
                    + self.num_heads * self.v_head_dim * d)  # out
        hd = self.head_dim
        return (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d)

    def _ssm_params(self) -> int:
        d = self.d_model
        di = self.ssm_expand * d
        nh = di // self.ssm_headdim
        return (d * (2 * di + 2 * self.ssm_state + nh)  # in_proj (z,x,B,C,dt)
                + di * self.ssm_conv + di * d + nh + nh)  # conv, out, A, D
