"""Arch configs for the assigned pool."""
from repro.configs.base import ArchConfig  # noqa: F401
from repro.configs.registry import ARCHS, SHAPES, get_arch, shape_applicable, smoke_config  # noqa: F401
