"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed
experts top-6.  [arXiv:2405.04434; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: logical heads; cache is the 512-d latent
    d_ff=1536,
    vocab_size=102400,
    head_dim=128,  # qk_nope / v head dim
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    num_experts=160,
    num_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    capacity_factor=1.25,
)
