"""Attention: GQA (w/ optional QKV bias, qk-norm) and MLA (DeepSeek-V2),
with chunked (flash-style) training attention and KV-cache decode.

Sharding layout:
  * training/prefill activations: (batch="dp", seq, heads="tp", hd)
  * KV cache: (batch="dp", seq="sp", kv_heads, hd) — the cache SEQUENCE is
    context-parallel over the model axis, which is what lets 32k-token
    caches for 128-request batches fit per-chip HBM at decode time; the
    softmax over the sharded seq dim lowers to partial reductions + a
    small all-reduce (GSPMD).  KV heads are additionally sharded when
    divisible (decided by config, not here).
  * decode int8 cache: quantized per (position, head) with f32 scales.

The chunked attention scans over KV blocks with a running
(max, sum, acc) triple — the flash-attention recurrence in pure jnp —
so 32k prefill never materializes an (S, S) score matrix.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, init_rmsnorm, rmsnorm
from repro.models.sharding import maybe_shard

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    if cfg.use_mla:
        r = cfg.kv_lora_rank
        rd = cfg.qk_rope_head_dim
        vd = cfg.v_head_dim
        p = {
            "wq": dense_init(ks[0], (d, h * (hd + rd))),
            "w_kv_down": dense_init(ks[1], (d, r)),
            "w_k_rope": dense_init(ks[2], (d, rd)),
            "w_kv_up": dense_init(ks[3], (r, h * (hd + vd))),
            "wo": dense_init(ks[4], (h * vd, d)),
            "kv_norm": init_rmsnorm(r),
        }
        return p
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


# --------------------------------------------------------------------------
# Flash-style chunked core:  softmax(Q K^T + mask) V  without (S, S).
# --------------------------------------------------------------------------

def _chunked_attention(q, k, v, *, causal: bool, q_offset, chunk: int = 1024):
    """q: (b, sq, h, dh), k/v: (b, sk, h, dh) (kv already broadcast to h).

    Scans KV chunks with the running-max/sum flash recurrence.
    q_offset: absolute position of q[0] (for causal masking vs cache).
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # b h sq dh
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)

    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nck = (sk + pad) // chunk
    kf = kf.reshape(b, h, nck, chunk, dh).transpose(2, 0, 1, 3, 4)
    vf = vf.reshape(b, h, nck, chunk, dh).transpose(2, 0, 1, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, s, acc = carry
        kc, vc, cidx = inputs
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kc)
        k_pos = cidx * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((sq, chunk), bool)
        valid = (k_pos < sk)[None, :]
        logits = jnp.where((mask & valid)[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        s_new = s * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    s0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, s, acc), _ = jax.lax.scan(
        body, (m0, s0, a0), (kf, vf, jnp.arange(nck)))
    out = acc / jnp.maximum(s, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # b sq h dh


def _dense_attention(q, k, v, *, causal: bool, q_offset):
    """Reference einsum attention for short sequences / decode."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask = jnp.arange(sk)[None, :] <= q_pos[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _broadcast_kv(k, h):
    """(b, s, kv, dh) -> (b, s, h, dh) by repeating groups."""
    b, s, kv, dh = k.shape
    if kv == h:
        return k
    rep = h // kv
    return jnp.repeat(k, rep, axis=2)


# --------------------------------------------------------------------------
# KV cache (bf16 or int8-quantized)
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (b, max_s, kv, dh)  cache dtype
    v: jax.Array
    k_scale: jax.Array | None  # (b, max_s, kv, 1) f32 when int8
    v_scale: jax.Array | None
    length: jax.Array  # () int32 — filled positions


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int,
                  kv_heads: int, head_dim: int) -> KVCache:
    dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    shape = (batch, max_seq, kv_heads, head_dim)
    scales = None
    if dt == jnp.int8:
        scales = jnp.zeros((batch, max_seq, kv_heads, 1), jnp.float32)
    k = maybe_shard(jnp.zeros(shape, dt), "dp", "sp", None, None)
    v = maybe_shard(jnp.zeros(shape, dt), "dp", "sp", None, None)
    return KVCache(k=k, v=v,
                   k_scale=scales, v_scale=scales,
                   length=jnp.zeros((), jnp.int32))


def _quantize(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32)
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale * 127.0), -127, 127)
    return q.astype(jnp.int8), scale / 127.0


def _dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cache_update(cache: KVCache, k_new, v_new, pos) -> KVCache:
    """Insert k/v at [pos : pos + s_new) (dynamic_update_slice)."""
    if cache.k.dtype == jnp.int8:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        k = jax.lax.dynamic_update_slice(cache.k, kq, (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, vq, (0, pos, 0, 0))
        k_sc = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, pos, 0, 0))
        v_sc = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, pos, 0, 0))
        return KVCache(k, v, k_sc, v_sc, pos + k_new.shape[1])
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0))
    return KVCache(k, v, None, None, pos + k_new.shape[1])


def cache_kv(cache: KVCache, dtype):
    if cache.k.dtype == jnp.int8:
        return (_dequantize(cache.k, cache.k_scale, dtype),
                _dequantize(cache.v, cache.v_scale, dtype))
    return cache.k.astype(dtype), cache.v.astype(dtype)


# --------------------------------------------------------------------------
# GQA forward
# --------------------------------------------------------------------------

def _project_qkv(p, cfg: ArchConfig, x, positions):
    dt = x.dtype
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    b, s, _ = x.shape
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.rms_eps)
        k = rmsnorm(p["k_norm"], k, cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = maybe_shard(q, "dp", None, "tp", None)
    k = maybe_shard(k, "dp", None, None, None)
    return q, k, v


def gqa_train(p, cfg: ArchConfig, x, *, causal: bool = True,
              chunk: int = 1024):
    """Full-sequence attention (training / prefill scoring)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, cfg, x, positions)
    kb = _broadcast_kv(k, cfg.num_heads)
    vb = _broadcast_kv(v, cfg.num_heads)
    if s <= 2048:
        out = _dense_attention(q, kb, vb, causal=causal, q_offset=0)
    else:
        out = _chunked_attention(q, kb, vb, causal=causal, q_offset=0,
                                 chunk=chunk)
    out = out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype)), (k, v)


def _cp_specs(mesh, batch: int, seq: int):
    """(batch_axes, seq_axis) for context-parallel decode under `mesh`,
    honoring divisibility; None where unshardable."""
    from jax.sharding import PartitionSpec as P
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_ext = 1
    for a in dp:
        dp_ext *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    b_ax = (dp if len(dp) > 1 else dp[0]) if dp and batch % dp_ext == 0 \
        else None
    tp_ext = dict(zip(mesh.axis_names, mesh.axis_sizes)).get("model", 1)
    s_ax = "model" if "model" in names and seq % tp_ext == 0 else None
    return b_ax, s_ax


def _decode_attention_cp(cfg: ArchConfig, q, cache: KVCache, mesh):
    """CONTEXT-PARALLEL decode attention: the cache stays sharded along
    the sequence axis; each model-shard computes a partial softmax
    (max / sum / weighted值) over its local KV slice and the shards
    combine with one tiny psum — the full K/V is never gathered.

    This is the #Perf iteration that brought qwen1.5-32b decode_32k from
    23 GB/device (args+temp, OOM on v5e) to fitting: the GSPMD fallback
    all-gathers the dequantized bf16 cache (~12 GB temp), the shard_map
    form keeps the per-device temp at the local slice (~0.8 GB).
    """
    import functools as ft
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    b, _, h, hd = q.shape
    sk = cache.k.shape[1]
    b_ax, s_ax = _cp_specs(mesh, b, sk)
    if s_ax is None:
        return None  # fall back to the gather path
    axes = (s_ax,) if s_ax else ()
    kv_spec = P(b_ax, s_ax, None, None)
    q_spec = P(b_ax, None, None, None)
    scale_specs = (kv_spec, kv_spec) if cache.k_scale is not None else \
        (None, None)

    @ft.partial(
        shard_map, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, scale_specs[0], scale_specs[1],
                  P(), P()),
        out_specs=q_spec, check_vma=False)
    def attend(qb, k_loc, v_loc, k_sc, v_sc, length, s_offsets):
        # local slice index -> global position for the length mask
        idx = jax.lax.axis_index(s_ax) if s_ax else 0
        s_loc = k_loc.shape[1]
        pos = s_offsets + idx * s_loc + jnp.arange(s_loc)
        if k_sc is not None:
            k_f = k_loc.astype(jnp.float32) * k_sc
            v_f = v_loc.astype(jnp.float32) * v_sc
        else:
            k_f = k_loc.astype(jnp.float32)
            v_f = v_loc.astype(jnp.float32)
        kb = _broadcast_kv(k_f, h)
        vb = _broadcast_kv(v_f, h)
        scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
        logits = jnp.einsum("bqhd,bkhd->bhqk",
                            qb.astype(jnp.float32) * scale, kb)
        logits = jnp.where((pos < length)[None, None, None, :], logits,
                           NEG_INF)
        m_loc = logits.max(axis=-1)  # (b, h, 1)
        m_glb = jax.lax.pmax(m_loc, s_ax)
        p_ = jnp.exp(logits - m_glb[..., None])
        s_loc_sum = p_.sum(axis=-1)
        acc = jnp.einsum("bhqk,bkhd->bqhd", p_, vb)
        s_glb = jax.lax.psum(s_loc_sum, s_ax)
        acc = jax.lax.psum(acc, s_ax)
        out = acc / jnp.maximum(s_glb, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(qb.dtype)

    zero = jnp.zeros((), jnp.int32)
    return attend(q, cache.k, cache.v, cache.k_scale, cache.v_scale,
                  cache.length, zero)


def gqa_decode(p, cfg: ArchConfig, x, cache: KVCache):
    """Single-step decode: x (b, 1, d) against the cache.  Uses the
    context-parallel partial-softmax path when a mesh is active and the
    cache sequence is shardable; plain gather path otherwise."""
    b = x.shape[0]
    pos = jnp.broadcast_to(cache.length[None], (b, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, pos)
    cache = cache_update(cache, k_new, v_new, cache.length)

    mesh = compat.get_abstract_mesh()
    out = None
    if not mesh.empty:
        out = _decode_attention_cp(cfg, q, cache, mesh)
    if out is None:
        k, v = cache_kv(cache, x.dtype)
        kb = _broadcast_kv(k, cfg.num_heads)
        vb = _broadcast_kv(v, cfg.num_heads)
        sk = kb.shape[1]
        logits_mask = jnp.arange(sk) < cache.length
        scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
        logits = jnp.einsum("bqhd,bkhd->bhqk",
                            q.astype(jnp.float32) * scale,
                            kb.astype(jnp.float32))
        logits = jnp.where(logits_mask[None, None, None, :], logits,
                           NEG_INF)
        pr = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", pr, vb.astype(jnp.float32))
        out = out.astype(x.dtype)
    out = out.reshape(b, 1, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(x.dtype)), cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache of (kv_lora + rope) dims
# --------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array  # (b, max_s, r) compressed latents
    k_rope: jax.Array  # (b, max_s, rd)
    length: jax.Array


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int) -> MLACache:
    c = maybe_shard(
        jnp.zeros((batch, max_seq, cfg.kv_lora_rank), jnp.bfloat16),
        "dp", "sp", None)
    kr = maybe_shard(
        jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), jnp.bfloat16),
        "dp", "sp", None)
    return MLACache(c_kv=c, k_rope=kr, length=jnp.zeros((), jnp.int32))


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    dt = x.dtype
    h, hd, rd, vd = (cfg.num_heads, cfg.head_dim, cfg.qk_rope_head_dim,
                     cfg.v_head_dim)
    b, s, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(
        b, s, h, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_kv_down"].astype(dt))
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.rms_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_k_rope"].astype(dt))
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg: ArchConfig, q_nope, q_rope, c_kv, k_rope, *,
                causal, q_offset, length=None):
    """Attention in the compressed space: expand c_kv to per-head K_nope/V."""
    dt = q_nope.dtype
    h, hd, vd = cfg.num_heads, cfg.head_dim, cfg.v_head_dim
    b, sk, r = c_kv.shape
    kv = jnp.einsum("bsr,re->bse", c_kv, p["w_kv_up"].astype(dt)).reshape(
        b, sk, h, hd + vd)
    k_nope, v = kv[..., :hd], kv[..., hd:]
    sq = q_nope.shape[1]
    scale = 1.0 / jnp.sqrt(hd + cfg.qk_rope_head_dim).astype(jnp.float32)
    logits = (
        jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                   k_nope.astype(jnp.float32))
        + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) * scale
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask = jnp.arange(sk)[None, :] <= q_pos[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    if length is not None:
        logits = jnp.where((jnp.arange(sk) < length)[None, None, None],
                           logits, NEG_INF)
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, v.astype(jnp.float32)).astype(dt)
    out = out.reshape(b, sq, h * vd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))


def mla_train(p, cfg: ArchConfig, x, *, causal: bool = True, chunk: int = 0):
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    # chunk queries to bound the (b, h, sq, sk) logits when s is large
    if s > 4096:
        qc = 1024
        nq = s // qc

        def body(i, acc):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * qc, qc, 1)
            o = _mla_attend(p, cfg, sl(q_nope), sl(q_rope), c_kv, k_rope,
                            causal=causal, q_offset=i * qc)
            return jax.lax.dynamic_update_slice_in_dim(acc, o, i * qc, 1)

        out = jax.lax.fori_loop(
            0, nq, body, jnp.zeros((b, s, cfg.d_model), x.dtype))
        return out, (c_kv, k_rope)
    out = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, causal=causal,
                      q_offset=0)
    return out, (c_kv, k_rope)


def mla_decode(p, cfg: ArchConfig, x, cache: MLACache):
    """Decode with WEIGHT ABSORPTION: attention runs entirely in the
    compressed (kv_lora + rope) space, never expanding per-head K/V for
    the cache — this is MLA's serving-memory advantage and keeps the
    per-step transient O(b * s * r) instead of O(b * s * h * (hd+vd))."""
    b = x.shape[0]
    dt = x.dtype
    h, hd, rd, vd, r = (cfg.num_heads, cfg.head_dim, cfg.qk_rope_head_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    pos = jnp.broadcast_to(cache.length[None], (b, 1))
    q_nope, q_rope, c_new, kr_new = _mla_qkv(p, cfg, x, pos)
    c_kv = jax.lax.dynamic_update_slice(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), (0, cache.length, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), (0, cache.length, 0))
    new_cache = MLACache(c_kv, k_rope, cache.length + 1)

    w_up = p["w_kv_up"].astype(dt).reshape(r, h, hd + vd)
    w_up_k, w_up_v = w_up[..., :hd], w_up[..., hd:]
    # absorb k-up into the query:  q_eff = q_nope @ W_up_k^T  (b,1,h,r)
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_up_k)
    ckv = c_kv.astype(dt)
    krope = k_rope.astype(dt)
    scale = 1.0 / jnp.sqrt(hd + rd).astype(jnp.float32)
    logits = (
        jnp.einsum("bqhr,bsr->bhqs", q_eff.astype(jnp.float32),
                   ckv.astype(jnp.float32))
        + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                     krope.astype(jnp.float32))
    ) * scale
    sk = ckv.shape[1]
    logits = jnp.where((jnp.arange(sk) < new_cache.length)[None, None, None],
                       logits, NEG_INF)
    pr = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", pr, ckv.astype(jnp.float32))  # latent
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_up_v.astype(jnp.float32))
    out = out.astype(dt).reshape(b, 1, h * vd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt)), new_cache
