"""Shared model layers: norms, rotary embeddings, gated MLP, embedding.

Pure-function style: `init_*` returns a param pytree; `apply` functions
take (params, x).  Compute dtype is bf16 with f32 accumulations and f32
norm statistics; params are stored f32 (cast at use).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.sharding import maybe_shard

Initializer = jax.nn.initializers.Initializer

COMPUTE_DTYPE = jnp.bfloat16


def dense_init(key, shape, in_axis=0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# --- RMSNorm ----------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


# --- Rotary position embeddings ---------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- Gated MLP (SwiGLU) -----------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model)),
    }
    if gated:
        p["w_gate"] = dense_init(k1, (d_model, d_ff))
    return p


def mlp(params, x):
    """SwiGLU when 'w_gate' present, classic GELU MLP otherwise; hidden
    dim tensor-sharded ("tp")."""
    dt = x.dtype
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt))
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(u)
    h = maybe_shard(h, "dp", None, "tp")
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dt))


# --- Embedding --------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int):
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.01}


def embed(params, tokens):
    return params["table"].astype(COMPUTE_DTYPE)[tokens]


def unembed(params, x):
    """Logits; vocab dim tensor-sharded."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))
    return maybe_shard(logits, "dp", None, "tp")
