"""Mamba2 (SSD — state-space duality) blocks: chunked parallel training
form and O(1)-state decode, plus the depthwise causal conv frontend.

Shapes follow the Mamba2 paper: d_inner = expand * d_model, heads of size
`headdim` (nheads = d_inner / headdim), scalar-identity A per head, one
B/C group shared across heads (n = ssm_state).

Training uses the chunked SSD algorithm: intra-chunk dual (attention-like)
term + inter-chunk state recurrence via a scan over chunk states —
O(S * chunk) instead of O(S^2), which is what makes the ``long_500k``
shape feasible for SSM/hybrid archs (sub-quadratic).

TP layout: the [z|x] projection is ONE matrix with the z/x boundary at
d_inner (a shard boundary whenever d_inner % tp == 0), so both halves
shard cleanly over "tp"; the small B/C/dt projection stays replicated.
The recurrent state is (batch, heads, headdim, n), heads sharded — decode
memory is independent of context length (the long_500k story).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, init_rmsnorm, rmsnorm
from repro.models.sharding import maybe_shard


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_headdim
    return d_in, nheads, cfg.ssm_state


def init_ssm(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, nheads, n = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_zx": dense_init(ks[0], (d, 2 * d_in)),  # [z | x], tp-sharded
        "w_bcdt": dense_init(ks[1], (d, 2 * n + nheads)),  # small, replicated
        "conv_w_x": jax.random.normal(ks[3], (cfg.ssm_conv, d_in),
                                      jnp.float32) * 0.1,
        "conv_b_x": jnp.zeros((d_in,), jnp.float32),
        "conv_w_bc": jax.random.normal(
            jax.random.fold_in(ks[3], 1), (cfg.ssm_conv, 2 * n),
            jnp.float32) * 0.1,
        "conv_b_bc": jnp.zeros((2 * n,), jnp.float32),
        "a_log": jnp.zeros((nheads,), jnp.float32),  # A = -exp(a_log) = -1
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.full((nheads,), -2.0, jnp.float32),  # softplus ~ 0.12
        "norm": init_rmsnorm(d_in),
        "w_out": dense_init(ks[2], (d_in, d)),
    }


def _segsum(x):
    """(..., l) -> (..., l, l) lower-triangular inclusive segment sums:
    out[..., i, j] = sum_{j < m <= i} x[..., m]  (NEG_INF above diag)."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_(j, i]
    i = jnp.arange(l)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xh, a_dt, b_mat, c_mat, chunk: int):
    """Chunked SSD scan.

    xh:   (b, s, h, p)  inputs already scaled by dt
    a_dt: (b, s, h)     log-decay per step (A * dt, negative)
    b_mat/c_mat: (b, s, n)  single group shared across heads
    Returns y: (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = xh.shape
    n = b_mat.shape[-1]
    l = min(chunk, s)
    pad = (-s) % l
    if pad:  # zero-padding is exact: decay exp(0)=1, x=0 adds nothing
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    c = s_pad // l
    xc = xh.reshape(b, c, l, h, p)
    ac = a_dt.reshape(b, c, l, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    bc = b_mat.reshape(b, c, l, n)
    cc = c_mat.reshape(b, c, l, n)

    a_cs = jnp.cumsum(ac, axis=-1)  # (b,h,c,l)

    # 1. intra-chunk (dual / attention-like) term
    decay = jnp.exp(_segsum(ac))  # (b,h,c,l,l), lower-tri
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cc, bc, decay, xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)  # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3. inter-chunk recurrence (scan over chunk axis)
    chunk_decay = jnp.exp(a_cs[..., -1])  # (b,h,c)

    def step(hprev, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), xh.dtype)
    hfinal, hprevs = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = hprevs.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    # 4. inter-chunk contribution
    state_decay = jnp.exp(a_cs)  # (b,h,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s_pad, h, p)[:, :s]
    return y, hfinal


class SSMCache(NamedTuple):
    state: jax.Array  # (b, h, p, n)
    conv_x: jax.Array  # (b, conv-1, d_in) trailing x inputs (pre-conv)
    conv_bc: jax.Array  # (b, conv-1, 2n)
    length: jax.Array  # () int32


def init_ssm_cache(cfg: ArchConfig, batch: int) -> SSMCache:
    d_in, nheads, n = _dims(cfg)
    return SSMCache(
        state=maybe_shard(
            jnp.zeros((batch, nheads, cfg.ssm_headdim, n), jnp.float32),
            "dp", "tp", None, None),
        conv_x=maybe_shard(
            jnp.zeros((batch, cfg.ssm_conv - 1, d_in), jnp.bfloat16),
            "dp", None, "tp"),
        conv_bc=jnp.zeros((batch, cfg.ssm_conv - 1, 2 * n), jnp.bfloat16),
        length=jnp.zeros((), jnp.int32),
    )


def _split_proj(p, cfg: ArchConfig, x):
    """Returns z, x_part (both tp-sharded), bc, dt_raw (replicated)."""
    d_in, nheads, n = _dims(cfg)
    zx = jnp.einsum("bsd,de->bse", x, p["w_zx"].astype(x.dtype))
    zx = maybe_shard(zx, "dp", None, "tp")
    z, x_part = zx[..., :d_in], zx[..., d_in:]
    bcdt = jnp.einsum("bsd,de->bse", x, p["w_bcdt"].astype(x.dtype))
    bc = bcdt[..., : 2 * n]
    dt_raw = bcdt[..., 2 * n:]
    return z, x_part, bc, dt_raw


def _conv_train(w, b, u):
    """Depthwise causal conv over the sequence (kernel K)."""
    wt = w.astype(u.dtype)
    k = wt.shape[0]
    padded = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(padded[:, i: i + u.shape[1], :] * wt[i] for i in range(k))
    return jax.nn.silu(out + b.astype(u.dtype))


def _ssd_from_parts(p, cfg, x_conv, bc_conv, dt_raw, want_state=False):
    d_in, nheads, n = _dims(cfg)
    b, s, _ = x_conv.shape
    b_mat = bc_conv[..., :n].astype(jnp.float32)
    c_mat = bc_conv[..., n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,s,h)
    a = -jnp.exp(p["a_log"])  # (h,)
    xh = x_conv.reshape(b, s, nheads, cfg.ssm_headdim).astype(jnp.float32)
    xh = maybe_shard(xh, "dp", None, "tp", None)
    y, hfinal = _ssd_chunked(xh * dt[..., None], a * dt, b_mat, c_mat,
                             cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None] * xh
    return y.reshape(b, s, d_in), hfinal


def _gate_out(p, cfg, y, z, dtype):
    y = rmsnorm(p["norm"], y.astype(dtype) * jax.nn.silu(z), cfg.rms_eps)
    return jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dtype))


def ssm_train(p, cfg: ArchConfig, x):
    """x: (b, s, d) -> (b, s, d) with the chunked SSD scan."""
    z, x_part, bc, dt_raw = _split_proj(p, cfg, x)
    x_conv = _conv_train(p["conv_w_x"], p["conv_b_x"], x_part)
    bc_conv = _conv_train(p["conv_w_bc"], p["conv_b_bc"], bc)
    y, _ = _ssd_from_parts(p, cfg, x_conv, bc_conv, dt_raw)
    return _gate_out(p, cfg, y, z, x.dtype)


def ssm_prefill(p, cfg: ArchConfig, x, cache: SSMCache):
    """Like ssm_train but also returns the post-prompt recurrent state and
    conv trailing windows, so decode can continue from the prompt."""
    z, x_part, bc, dt_raw = _split_proj(p, cfg, x)
    x_conv = _conv_train(p["conv_w_x"], p["conv_b_x"], x_part)
    bc_conv = _conv_train(p["conv_w_bc"], p["conv_b_bc"], bc)
    y, hfinal = _ssd_from_parts(p, cfg, x_conv, bc_conv, dt_raw)
    out = _gate_out(p, cfg, y, z, x.dtype)
    k = cfg.ssm_conv - 1
    new_cache = SSMCache(
        state=hfinal,
        conv_x=x_part[:, -k:, :].astype(jnp.bfloat16),
        conv_bc=bc[:, -k:, :].astype(jnp.bfloat16),
        length=cache.length + x.shape[1])
    return out, new_cache


def ssm_decode(p, cfg: ArchConfig, x, cache: SSMCache):
    """Single-token step: x (b, 1, d); O(1) in context length."""
    d_in, nheads, n = _dims(cfg)
    b = x.shape[0]
    dt_ = x.dtype
    z, x_part, bc, dt_raw = _split_proj(p, cfg, x)

    def conv_step(w, bias, window, new):
        cat = jnp.concatenate([window.astype(dt_), new], axis=1)  # (b,K,ch)
        out = jnp.sum(cat * w.astype(dt_)[None], axis=1, keepdims=True)
        return jax.nn.silu(out + bias.astype(dt_)), cat[:, 1:, :]

    x_conv, new_win_x = conv_step(p["conv_w_x"], p["conv_b_x"],
                                  cache.conv_x, x_part)
    bc_conv, new_win_bc = conv_step(p["conv_w_bc"], p["conv_b_bc"],
                                    cache.conv_bc, bc)

    b_vec = bc_conv[:, 0, :n].astype(jnp.float32)
    c_vec = bc_conv[:, 0, n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(a * dt)  # (b,h)
    xh = x_conv[:, 0].reshape(b, nheads, cfg.ssm_headdim).astype(jnp.float32)
    state = cache.state * da[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xh * dt[..., None], b_vec)
    y = jnp.einsum("bhpn,bn->bhp", state, c_vec) \
        + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, d_in)
    out = _gate_out(p, cfg, y, z, dt_)
    return out, SSMCache(state=state, conv_x=new_win_x.astype(jnp.bfloat16),
                         conv_bc=new_win_bc.astype(jnp.bfloat16),
                         length=cache.length + 1)


def ssm_reference_scan(p, cfg: ArchConfig, x):
    """Sequential (step-by-step) oracle for tests: runs ssm_decode over
    the sequence.  O(S) steps — small inputs only."""
    b, s, d = x.shape
    cache = init_ssm_cache(cfg, b)
    outs = []
    for t in range(s):
        o, cache = ssm_decode(p, cfg, x[:, t: t + 1, :], cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
