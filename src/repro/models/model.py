"""Unified model API over all assigned architecture families.

    params = init(key, cfg)
    loss, metrics = train_loss(params, cfg, batch)
    logits, caches = prefill(params, cfg, batch)        # serving
    logits, caches = decode_step(params, cfg, caches, tokens)

`batch` always carries "tokens" and "labels"; modality archs add stub
frontend tensors ("frames" for whisper, "patches" for llava) produced by
input_specs() — the frontends themselves are stubs per the assignment.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import (COMPUTE_DTYPE, dense_init, embed,
                                 init_embedding, init_rmsnorm, rmsnorm)
from repro.models.sharding import maybe_shard


def _block_kind(cfg: ArchConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe",
            "ssm": "ssm"}.get(cfg.family, "dense")


def _hybrid_layout(cfg: ArchConfig):
    n_groups = cfg.num_layers // cfg.attn_every
    per_group = cfg.attn_every - 1
    trailing = cfg.num_layers - n_groups * cfg.attn_every
    return n_groups, per_group, trailing


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"table": dense_init(ks[1], (cfg.vocab_size,
                                                    cfg.d_model), in_axis=1)}
    if cfg.family == "hybrid":
        n_groups, per_group, trailing = _hybrid_layout(cfg)
        p["ssm_layers"] = tfm.init_stack(ks[2], cfg, "ssm",
                                         n_groups * per_group + trailing)
        p["shared_attn"] = tfm.init_block(ks[3], cfg, "dense")
    elif cfg.family == "encdec":
        p["enc_layers"] = tfm.init_stack(ks[2], cfg, "dense",
                                         cfg.encoder_layers)
        p["enc_norm"] = init_rmsnorm(cfg.d_model)
        p["layers"] = tfm.init_stack(ks[3], cfg, "cross", cfg.num_layers)
    else:
        p["layers"] = tfm.init_stack(ks[2], cfg, _block_kind(cfg),
                                     cfg.num_layers)
    return p


# --------------------------------------------------------------------------
# Embedding + modality stubs
# --------------------------------------------------------------------------

def _input_embeddings(p, cfg: ArchConfig, batch) -> jax.Array:
    x = embed(p["embed"], batch["tokens"])  # (b, s, d) bf16
    if cfg.family == "vlm" and "patches" in batch:
        # anyres stub: precomputed patch embeddings replace the first
        # num_patch_tokens positions
        np_ = cfg.num_patch_tokens
        patches = batch["patches"].astype(x.dtype)
        x = jnp.concatenate([patches, x[:, np_:, :]], axis=1)
    return maybe_shard(x, "dp", None, None)


def _encode(p, cfg: ArchConfig, frames) -> jax.Array:
    """Whisper encoder over stub frame embeddings (conv frontend stubbed)."""
    x = maybe_shard(frames.astype(COMPUTE_DTYPE), "dp", None, None)

    def one(x, layer_p):
        # non-causal self attention encoder block
        h = rmsnorm(layer_p["pre_norm"], x, cfg.rms_eps)
        a, _ = attn_mod.gqa_train(layer_p["attn"], cfg, h, causal=False)
        x = x + a
        h = rmsnorm(layer_p["post_norm"], x, cfg.rms_eps)
        from repro.models.layers import mlp
        return x + mlp(layer_p["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(one), x, p["enc_layers"])
    return rmsnorm(p["enc_norm"], x, cfg.rms_eps)


def _backbone_train(p, cfg: ArchConfig, x, batch):
    """Run the stack; returns (hidden, aux_loss)."""
    if cfg.family == "hybrid":
        return _hybrid_train(p, cfg, x)
    if cfg.family == "encdec":
        enc_out = _encode(p, cfg, batch["frames"])
        return tfm.stack_train(p["layers"], cfg, x, "cross", cross=enc_out)
    return tfm.stack_train(p["layers"], cfg, x, _block_kind(cfg))


def _hybrid_train(p, cfg: ArchConfig, x):
    n_groups, per_group, trailing = _hybrid_layout(cfg)
    ssm_p = p["ssm_layers"]
    grouped = jax.tree.map(
        lambda a: a[: n_groups * per_group].reshape(
            (n_groups, per_group) + a.shape[1:]), ssm_p)
    shared = p["shared_attn"]

    @jax.checkpoint
    def group(x, gp):
        x, _ = tfm.stack_train(gp, cfg, x, "ssm", remat=False)
        x, _ = tfm.block_train(shared, cfg, x, "dense")
        return maybe_shard(x, "dp", None, None), None

    x, _ = jax.lax.scan(group, x, grouped)
    if trailing:
        tail = jax.tree.map(lambda a: a[n_groups * per_group:], ssm_p)
        x, _ = tfm.stack_train(tail, cfg, x, "ssm")
    return x, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# Training loss (chunked vocab projection)
# --------------------------------------------------------------------------

def _chunked_xent(p, cfg: ArchConfig, hidden, labels, chunk: int = 512):
    """Cross entropy with the (b, s, vocab) logits never materialized for
    the full sequence: scan over sequence chunks."""
    table = (p["embed"]["table"] if cfg.tie_embeddings
             else p["unembed"]["table"])
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        h, lab = inp
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                            table.astype(jnp.float32))
        logits = maybe_shard(logits, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(p, cfg: ArchConfig, batch, aux_weight: float = 0.01):
    x = _input_embeddings(p, cfg, batch)
    h, aux = _backbone_train(p, cfg, x, batch)
    h = rmsnorm(p["final_norm"], h, cfg.rms_eps)
    loss = _chunked_xent(p, cfg, h, batch["labels"])
    return loss + aux_weight * aux, {"xent": loss, "aux": aux}


# --------------------------------------------------------------------------
# Serving: prefill + decode
# --------------------------------------------------------------------------

class ServeState(NamedTuple):
    caches: Any  # stacked per-layer cache pytree (family-specific)
    cross_kv: Any  # whisper only
    attn_caches: Any  # hybrid shared-attention caches (stacked per group)


def init_caches(cfg: ArchConfig, batch: int, max_seq: int):
    """Allocate empty caches for `batch` requests of context max_seq."""
    kind = _block_kind(cfg)
    L = cfg.num_layers

    def stack_cache(make_one, n):
        caches = [make_one() for _ in range(1)]
        proto = caches[0]
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), proto)

    if cfg.family == "hybrid":
        n_groups, per_group, trailing = _hybrid_layout(cfg)
        n_ssm = n_groups * per_group + trailing
        ssm_caches = stack_cache(lambda: ssm_mod.init_ssm_cache(cfg, batch),
                                 n_ssm)
        attn_caches = stack_cache(
            lambda: attn_mod.init_kv_cache(cfg, batch, max_seq,
                                           cfg.num_kv_heads, cfg.head_dim),
            n_groups)
        return ServeState(caches=ssm_caches, cross_kv=None,
                          attn_caches=attn_caches)
    if cfg.family == "ssm":
        return ServeState(
            caches=stack_cache(lambda: ssm_mod.init_ssm_cache(cfg, batch), L),
            cross_kv=None, attn_caches=None)
    if cfg.use_mla:
        return ServeState(
            caches=stack_cache(
                lambda: attn_mod.init_mla_cache(cfg, batch, max_seq), L),
            cross_kv=None, attn_caches=None)
    return ServeState(
        caches=stack_cache(
            lambda: attn_mod.init_kv_cache(cfg, batch, max_seq,
                                           cfg.num_kv_heads, cfg.head_dim), L),
        cross_kv=None, attn_caches=None)


def decode_step(p, cfg: ArchConfig, state: ServeState, tokens):
    """tokens: (b, 1) -> next-token logits (b, vocab) + updated caches."""
    x = embed(p["embed"], tokens)
    x = maybe_shard(x, "dp", None, None)
    if cfg.family == "hybrid":
        x, state = _hybrid_decode(p, cfg, x, state)
    elif cfg.family == "encdec":
        x, caches = tfm.stack_decode(p["layers"], cfg, x, "cross",
                                     state.caches, cross_kv=state.cross_kv)
        state = state._replace(caches=caches)
    else:
        x, caches = tfm.stack_decode(p["layers"], cfg, x, _block_kind(cfg),
                                     state.caches)
        state = state._replace(caches=caches)
    x = rmsnorm(p["final_norm"], x, cfg.rms_eps)
    table = (p["embed"]["table"] if cfg.tie_embeddings
             else p["unembed"]["table"])
    logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    return maybe_shard(logits[:, 0, :], "dp", "tp"), state


def _hybrid_decode(p, cfg: ArchConfig, x, state: ServeState):
    n_groups, per_group, trailing = _hybrid_layout(cfg)
    ssm_p = p["ssm_layers"]
    grouped_p = jax.tree.map(
        lambda a: a[: n_groups * per_group].reshape(
            (n_groups, per_group) + a.shape[1:]), ssm_p)
    grouped_c = jax.tree.map(
        lambda a: a[: n_groups * per_group].reshape(
            (n_groups, per_group) + a.shape[1:]), state.caches)
    shared = p["shared_attn"]

    def group(i, carry):
        x, gcs, acs = carry
        gp = tfm._index_tree(grouped_p, i)
        gc = tfm._index_tree(gcs, i)
        ac = tfm._index_tree(acs, i)
        x, gc = tfm.stack_decode(gp, cfg, x, "ssm", gc)
        x, ac = tfm.block_decode(shared, cfg, x, "dense", ac)
        return x, tfm._update_tree(gcs, gc, i), tfm._update_tree(acs, ac, i)

    x, gcs, acs = jax.lax.fori_loop(
        0, n_groups, group, (x, grouped_c, state.attn_caches))
    new_ssm = jax.tree.map(
        lambda a: a.reshape((n_groups * per_group,) + a.shape[2:]), gcs)
    if trailing:
        tail_p = jax.tree.map(lambda a: a[n_groups * per_group:], ssm_p)
        tail_c = jax.tree.map(lambda a: a[n_groups * per_group:], state.caches)
        x, tail_c = tfm.stack_decode(tail_p, cfg, x, "ssm", tail_c)
        new_ssm = jax.tree.map(
            lambda a, t: jnp.concatenate([a, t], axis=0), new_ssm, tail_c)
    return x, ServeState(caches=new_ssm, cross_kv=None, attn_caches=acs)


def prefill(p, cfg: ArchConfig, batch, max_seq: int = 0):
    """Process the full prompt, build caches, return last-position logits.

    For simplicity and HLO-size parity with training, prefill runs the
    train-mode stack and then RE-SCANS to collect caches only for the
    attention families that need explicit K/V (dense/moe/vlm/mla); SSM
    archs get their states from a chunked scan that returns final states.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = max_seq or s
    x = _input_embeddings(p, cfg, batch)
    kind = _block_kind(cfg)

    if cfg.family == "encdec":
        from repro.models.layers import mlp
        enc_out = _encode(p, cfg, batch["frames"])
        state = init_caches(cfg, b, max_seq)

        def one(x, inp):
            layer_p, cache = inp
            h = rmsnorm(layer_p["pre_norm"], x, cfg.rms_eps)
            a, (k, v) = attn_mod.gqa_train(layer_p["attn"], cfg, h)
            cache = attn_mod.cache_update(
                cache._replace(length=jnp.zeros((), jnp.int32)), k, v, 0)
            x = x + a
            h = rmsnorm(layer_p["cross_norm"], x, cfg.rms_eps)
            x = x + tfm._cross_attention(layer_p["cross"], cfg, h, enc_out)
            ckv = tfm.precompute_cross_kv(layer_p["cross"], cfg, enc_out)
            h2 = rmsnorm(layer_p["post_norm"], x, cfg.rms_eps)
            return x + mlp(layer_p["mlp"], h2), (cache, ckv)

        x, (caches, cross_kv) = jax.lax.scan(one, x, (p["layers"],
                                                      state.caches))
        h = rmsnorm(p["final_norm"], x, cfg.rms_eps)
        return _last_logits(p, cfg, h), state._replace(
            caches=caches, cross_kv=cross_kv)

    if cfg.family == "ssm":
        state = init_caches(cfg, b, max_seq)

        def one(x, inp):
            layer_p, cache = inp
            h = rmsnorm(layer_p["pre_norm"], x, cfg.rms_eps)
            y, new_cache = ssm_mod.ssm_prefill(layer_p["ssm"], cfg, h, cache)
            return x + y, new_cache

        x, caches = jax.lax.scan(one, x, (p["layers"], state.caches))
        h = rmsnorm(p["final_norm"], x, cfg.rms_eps)
        return _last_logits(p, cfg, h), state._replace(caches=caches)

    if cfg.family == "hybrid":
        return _hybrid_prefill(p, cfg, x, b, max_seq)

    # attention families: scan collecting per-layer K/V
    state = init_caches(cfg, b, max_seq)

    def one(x, inp):
        layer_p, cache = inp
        h = rmsnorm(layer_p["pre_norm"], x, cfg.rms_eps)
        if cfg.use_mla:
            a, (c_kv, k_rope) = attn_mod.mla_train(layer_p["attn"], cfg, h)
            cache = attn_mod.MLACache(
                c_kv=jax.lax.dynamic_update_slice(
                    cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, 0, 0)),
                k_rope=jax.lax.dynamic_update_slice(
                    cache.k_rope, k_rope.astype(cache.k_rope.dtype),
                    (0, 0, 0)),
                length=jnp.asarray(s, jnp.int32))
        else:
            a, (k, v) = attn_mod.gqa_train(layer_p["attn"], cfg, h)
            cache = attn_mod.cache_update(
                cache._replace(length=jnp.zeros((), jnp.int32)), k, v, 0)
        x = x + a
        h2 = rmsnorm(layer_p["post_norm"], x, cfg.rms_eps)
        if kind == "moe":
            from repro.models import moe as moe_mod
            f, _ = moe_mod.moe_ffn(layer_p["moe"], cfg, h2)
        else:
            from repro.models.layers import mlp
            f = mlp(layer_p["mlp"], h2)
        return x + f, cache

    x, caches = jax.lax.scan(one, x, (p["layers"], state.caches))
    h = rmsnorm(p["final_norm"], x, cfg.rms_eps)
    return _last_logits(p, cfg, h), state._replace(caches=caches)


def _hybrid_prefill(p, cfg: ArchConfig, x, b, max_seq):
    n_groups, per_group, trailing = _hybrid_layout(cfg)
    state = init_caches(cfg, b, max_seq)
    grouped_p = jax.tree.map(
        lambda a: a[: n_groups * per_group].reshape(
            (n_groups, per_group) + a.shape[1:]), p["ssm_layers"])
    grouped_c = jax.tree.map(
        lambda a: a[: n_groups * per_group].reshape(
            (n_groups, per_group) + a.shape[1:]), state.caches)
    shared = p["shared_attn"]
    s = x.shape[1]

    def ssm_one(x, inp):
        layer_p, cache = inp
        h = rmsnorm(layer_p["pre_norm"], x, cfg.rms_eps)
        y, new_cache = ssm_mod.ssm_prefill(layer_p["ssm"], cfg, h, cache)
        return x + y, new_cache

    def group(x, inp):
        gp, gc, ac = inp
        x, gc = jax.lax.scan(ssm_one, x, (gp, gc))
        h = rmsnorm(shared["pre_norm"], x, cfg.rms_eps)
        a, (k, v) = attn_mod.gqa_train(shared["attn"], cfg, h)
        ac = attn_mod.cache_update(
            ac._replace(length=jnp.zeros((), jnp.int32)), k, v, 0)
        x = x + a
        h2 = rmsnorm(shared["post_norm"], x, cfg.rms_eps)
        from repro.models.layers import mlp
        x = x + mlp(shared["mlp"], h2)
        return x, (gc, ac)

    x, (gcs, acs) = jax.lax.scan(group, x, (grouped_p, grouped_c,
                                            state.attn_caches))
    new_ssm = jax.tree.map(
        lambda a: a.reshape((n_groups * per_group,) + a.shape[2:]), gcs)
    if trailing:
        tail_p = jax.tree.map(lambda a: a[n_groups * per_group:],
                              p["ssm_layers"])
        tail_c = jax.tree.map(lambda a: a[n_groups * per_group:],
                              state.caches)
        x, tail_c = jax.lax.scan(ssm_one, x, (tail_p, tail_c))
        new_ssm = jax.tree.map(
            lambda a, t: jnp.concatenate([a, t], axis=0), new_ssm, tail_c)
    h = rmsnorm(p["final_norm"], x, cfg.rms_eps)
    return _last_logits(p, cfg, h), ServeState(
        caches=new_ssm, cross_kv=None, attn_caches=acs)


def _last_logits(p, cfg: ArchConfig, h):
    table = (p["embed"]["table"] if cfg.tie_embeddings
             else p["unembed"]["table"])
    last = h[:, -1, :]
    logits = jnp.einsum("bd,vd->bv", last.astype(jnp.float32),
                        table.astype(jnp.float32))
    return maybe_shard(logits, "dp", "tp")
