"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; input_specs() provides precomputed
frame/patch embeddings).

These helpers define the shapes/dtypes of the stub tensors and a
deterministic synthetic generator for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def frontend_spec(cfg: ArchConfig, batch: int):
    """ShapeDtypeStruct-compatible (shape, dtype) for the stub tensors."""
    if cfg.family == "encdec":
        return {"frames": ((batch, cfg.encoder_seq, cfg.d_model),
                           jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"patches": ((batch, cfg.num_patch_tokens, cfg.d_model),
                            jnp.bfloat16)}
    return {}


def synthetic_frontend(key, cfg: ArchConfig, batch: int):
    out = {}
    for name, (shape, dtype) in frontend_spec(cfg, batch).items():
        out[name] = (jax.random.normal(key, shape, jnp.float32) * 0.02
                     ).astype(dtype)
    return out
