"""Decoder stacks: dense / MoE / SSM / hybrid blocks with scan-over-layers.

Scan keeps the HLO O(1) in depth (DeepSeek's 60 layers compile the same
program as 1), which bounds XLA compile time at 512-device scale.  Layer
params are stacked along a leading axis via vmapped init.

Hybrid (Zamba2-style): SSM layers scanned in groups of (attn_every - 1),
with ONE weight-shared attention+MLP block applied between groups.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import init_mlp, init_rmsnorm, mlp, rmsnorm
from repro.models.sharding import maybe_shard


# --------------------------------------------------------------------------
# Single blocks
# --------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {
            "pre_norm": init_rmsnorm(cfg.d_model),
            "ssm": ssm_mod.init_ssm(ks[0], cfg),
        }
    p = {
        "pre_norm": init_rmsnorm(cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg),
        "post_norm": init_rmsnorm(cfg.d_model),
    }
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp)
    if kind == "cross":  # decoder layer with cross attention (whisper)
        p["cross"] = init_cross_attention(ks[2], cfg)
        p["cross_norm"] = init_rmsnorm(cfg.d_model)
    return p


def block_train(p, cfg: ArchConfig, x, kind: str, cross: jax.Array | None = None):
    """One residual block; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h = rmsnorm(p["pre_norm"], x, cfg.rms_eps)
        return x + ssm_mod.ssm_train(p["ssm"], cfg, h), aux
    h = rmsnorm(p["pre_norm"], x, cfg.rms_eps)
    if cfg.use_mla:
        a, _ = attn.mla_train(p["attn"], cfg, h)
    else:
        a, _ = attn.gqa_train(p["attn"], cfg, h)
    x = x + a
    if cross is not None:
        h = rmsnorm(p["cross_norm"], x, cfg.rms_eps)
        c = _cross_attention(p["cross"], cfg, h, cross)
        x = x + c
    h = rmsnorm(p["post_norm"], x, cfg.rms_eps)
    if kind == "moe":
        f, aux = moe_mod.moe_ffn(p["moe"], cfg, h)
    else:
        f = mlp(p["mlp"], h)
    return x + f, aux


def block_decode(p, cfg: ArchConfig, x, kind: str, cache,
                 cross_kv=None):
    if kind == "ssm":
        h = rmsnorm(p["pre_norm"], x, cfg.rms_eps)
        o, cache = ssm_mod.ssm_decode(p["ssm"], cfg, h, cache)
        return x + o, cache
    h = rmsnorm(p["pre_norm"], x, cfg.rms_eps)
    if cfg.use_mla:
        a, cache = attn.mla_decode(p["attn"], cfg, h, cache)
    else:
        a, cache = attn.gqa_decode(p["attn"], cfg, h, cache)
    x = x + a
    if cross_kv is not None:
        h = rmsnorm(p["cross_norm"], x, cfg.rms_eps)
        x = x + _cross_attention_cached(p["cross"], cfg, h, cross_kv)
    h = rmsnorm(p["post_norm"], x, cfg.rms_eps)
    if kind == "moe":
        f, _ = moe_mod.moe_ffn(p["moe"], cfg, h)
    else:
        f = mlp(p["mlp"], h)
    return x + f, cache


# --------------------------------------------------------------------------
# Cross attention (whisper enc-dec)
# --------------------------------------------------------------------------

def init_cross_attention(key, cfg: ArchConfig):
    from repro.models.layers import dense_init
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, h * hd)),
        "wv": dense_init(ks[2], (d, h * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }


def _cross_attention(p, cfg: ArchConfig, x, enc_out):
    dt = x.dtype
    b, s, _ = x.shape
    se = enc_out.shape[1]
    h, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", enc_out,
                   p["wk"].astype(dt)).reshape(b, se, h, hd)
    v = jnp.einsum("bsd,de->bse", enc_out,
                   p["wv"].astype(dt)).reshape(b, se, h, hd)
    out = attn._dense_attention(q, k, v, causal=False, q_offset=0)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))


def _cross_attention_cached(p, cfg: ArchConfig, x, cross_kv):
    k, v = cross_kv
    dt = x.dtype
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(b, s, h, hd)
    out = attn._dense_attention(q, k.astype(dt), v.astype(dt), causal=False,
                                q_offset=0)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))


def precompute_cross_kv(p, cfg: ArchConfig, enc_out):
    dt = enc_out.dtype
    b, se, _ = enc_out.shape
    h, hd = cfg.num_heads, cfg.head_dim
    k = jnp.einsum("bsd,de->bse", enc_out,
                   p["wk"].astype(dt)).reshape(b, se, h, hd)
    v = jnp.einsum("bsd,de->bse", enc_out,
                   p["wv"].astype(dt)).reshape(b, se, h, hd)
    return k, v


# --------------------------------------------------------------------------
# Stacks (scan over stacked layer params)
# --------------------------------------------------------------------------

def init_stack(key, cfg: ArchConfig, kind: str, num_layers: int):
    keys = jax.random.split(key, num_layers)
    return jax.vmap(lambda k: init_block(k, cfg, kind))(keys)


def stack_train(params, cfg: ArchConfig, x, kind: str, remat: bool = True,
                cross: jax.Array | None = None):
    """Scan x through stacked layers; accumulates MoE aux losses."""

    def one(x, layer_p):
        out, aux = block_train(layer_p, cfg, x, kind, cross=cross)
        out = maybe_shard(out, "dp", None, None)
        return out, aux

    if remat and cfg.remat_policy == "full":
        one = jax.checkpoint(one)
    elif remat and cfg.remat_policy == "dots":
        one = jax.checkpoint(
            one, policy=jax.checkpoint_policies.checkpoint_dots)
    x, auxs = jax.lax.scan(one, x, params)
    return x, jnp.sum(auxs)


def _index_tree(tree_, i):
    return jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
        a, i, axis=0, keepdims=False), tree_)


def _update_tree(full, new, i):
    return jax.tree.map(
        lambda f, n: jax.lax.dynamic_update_index_in_dim(f, n, i, axis=0),
        full, new)


def stack_decode(params, cfg: ArchConfig, x, kind: str, caches,
                 cross_kv=None):
    """Step a single token through stacked layers.

    Uses fori_loop with the FULL stacked cache in the CARRY, updated via
    dynamic_update_slice — XLA aliases carry DUS in place, so the
    multi-GB serving cache is single-buffered.  (The natural scan with
    caches as xs/ys double-buffers: xs are read-only inputs and ys fresh
    outputs — measured +10.7 GB/device on qwen1.5-32b decode_32k.)
    cross_kv, if given, is stacked per-layer (whisper)."""
    num_layers = jax.tree.leaves(params)[0].shape[0]

    def body(i, carry):
        x, caches_full = carry
        layer_p = _index_tree(params, i)
        cache_i = _index_tree(caches_full, i)
        ckv = _index_tree(cross_kv, i) if cross_kv is not None else None
        out, new_cache = block_decode(layer_p, cfg, x, kind, cache_i,
                                      cross_kv=ckv)
        return out, _update_tree(caches_full, new_cache, i)

    x, caches = jax.lax.fori_loop(0, num_layers, body, (x, caches))
    return x, caches
