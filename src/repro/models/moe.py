"""Mixture-of-Experts FFN with sort-based (dropless-with-capacity) dispatch.

TPU-native design (DESIGN.md Sec. 3/4): no (tokens, experts, capacity)
one-hot dispatch tensors.  Instead:

  1. route: top-k softmax gates per token
  2. sort token-assignment pairs by expert id; compute each pair's rank
     within its expert via a sorted-segment trick (no E-wide one-hot)
  3. capacity-truncate (rank >= capacity dropped — standard capacity
     semantics; capacity_factor sizes the buffer)
  4. gather tokens into an (experts, capacity, d) buffer — EP-sharded on
     the "tp"/model axis, so this gather IS the all-to-all
  5. batched expert SwiGLU via einsum over the expert dim
  6. scatter-add back with gate weights

Shared experts (DeepSeek-style) run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init
from repro.models.sharding import maybe_shard


def init_moe(key, cfg: ArchConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": dense_init(ks[1], (e, d, f)),
        "w_up": dense_init(ks[2], (e, d, f)),
        "w_down": dense_init(ks[3], (e, f, d)),
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (d, fs)),
            "w_up": dense_init(k2, (d, fs)),
            "w_down": dense_init(k3, (fs, d)),
        }
    return p


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    cap = int(tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, (cap + 7) // 8 * 8)  # sublane-aligned


def _num_groups(batch: int) -> int:
    """Dispatch groups = data-parallel extent (GShard-style): routing,
    ranking and the capacity budget are LOCAL to each group, so the only
    cross-device movement is the (groups -> experts) buffer reshard — the
    MoE all-to-all.  Without groups, GSPMD must all-reduce global-token
    scatters, which is catastrophically oversized (observed 52 TiB/step
    on deepseek-v2 before this fix)."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty:
        return 1
    present = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dp = 1
    for a in ("pod", "data"):
        dp *= present.get(a, 1)
    while dp > 1 and batch % dp != 0:
        dp //= 2
    return max(dp, 1)


def _group_dispatch(tokens, logits, cfg: ArchConfig, cap: int):
    """Per-group sort-based dispatch.  tokens (t, d), logits (t, e)."""
    e, k = cfg.num_experts, cfg.moe_top_k
    t, d = tokens.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss terms (Switch-style), per group
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    flat_expert = expert_ids.reshape(-1)  # (t*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    idx = jnp.arange(t * k)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_expert[1:] != sorted_expert[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0))
    rank = idx - seg_start

    keep = rank < cap
    slot = jnp.where(keep, sorted_expert * cap + rank, e * cap)
    buf = jnp.zeros((e * cap + 1, tokens.shape[1]), tokens.dtype)
    buf = buf.at[slot].set(tokens[flat_token[order]])
    return (buf[: e * cap].reshape(e, cap, tokens.shape[1]),
            (keep, slot, flat_token, order, flat_gate), aux)


def _group_combine(out_buf, dispatch_info, t: int, cap: int,
                   cfg: ArchConfig):
    e = cfg.num_experts
    keep, slot, flat_token, order, flat_gate = dispatch_info
    out_flat = out_buf.reshape(e * cap, out_buf.shape[-1])
    pair_out = jnp.where(
        keep[:, None], out_flat[jnp.minimum(slot, e * cap - 1)], 0.0)
    return jnp.zeros((t, out_buf.shape[-1]), out_buf.dtype).at[
        flat_token[order]].add(
        pair_out * flat_gate[order][:, None].astype(out_buf.dtype))


def _sm_axes(mesh, batch: int):
    """(dp_axes, tp_axis) usable for the shard_map MoE under `mesh`."""
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dp_ext = 1
    for a in dp:
        dp_ext *= sizes[a]
    tp = "model" if "model" in names else None
    if not dp or batch % dp_ext != 0:
        dp = ()
    return dp, tp, sizes


def _moe_ffn_shard_map(p, cfg: ArchConfig, x, mesh, dp, tp):
    """shard_map MoE: per-device local dispatch + expert FFN + partial
    combine, ONE bf16 psum over the model axis per call.

    Key observation: activations are replicated over "model", so each
    (data i, model j) device already holds group i's tokens AND expert
    shard j — dispatch needs NO communication at all; only the combined
    (tokens, d) partial sums cross the model axis.  This replaced the
    GSPMD-partitioned gather-from-EP-buffer, which all-reduced f32
    (pairs, d) tensors three times per layer (measured 28 GiB/layer/dev
    on deepseek-v2 -> now 0.7 GiB bf16).
    """
    import functools as ft
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    dp_ext = 1
    for a in dp:
        dp_ext *= sizes[a]
    tp_ext = sizes.get(tp, 1) if tp else 1
    e_loc = e // tp_ext
    tg = (b // dp_ext) * s
    cap = _capacity(tg, cfg)
    dt = x.dtype

    x_spec = P((dp if len(dp) > 1 else dp[0]) if dp else None, None, None)
    w_e = P(tp, None, None)  # expert stacks sharded on the expert dim
    shared_specs = {"w_gate": P(None, tp), "w_up": P(None, tp),
                    "w_down": P(tp, None)} if cfg.num_shared_experts else None
    in_specs = (x_spec,
                {"router": P(None, None), "w_gate": w_e, "w_up": w_e,
                 "w_down": w_e,
                 **({"shared": shared_specs} if shared_specs else {})})

    @ft.partial(shard_map, mesh=mesh, in_specs=in_specs,
                out_specs=(x_spec, P()), check_vma=False)
    def body(x_loc, pl):
        t = x_loc.shape[0] * x_loc.shape[1]
        tokens = x_loc.reshape(t, d)
        logits = jnp.einsum("td,de->te", tokens.astype(jnp.float32),
                            pl["router"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
            1.0 / (t * k))
        aux = e * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)

        flat_expert = expert_ids.reshape(-1)
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(t), k)
        order = jnp.argsort(flat_expert, stable=True)
        sorted_expert = flat_expert[order]
        idx = jnp.arange(t * k)
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool),
             sorted_expert[1:] != sorted_expert[:-1]])
        seg_start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_start, idx, 0))
        rank = idx - seg_start
        keep = rank < cap

        shard = jax.lax.axis_index(tp) if tp else 0
        e_lo = shard * e_loc
        mine = keep & (sorted_expert >= e_lo) & (sorted_expert < e_lo + e_loc)
        local_slot = jnp.where(
            mine, (sorted_expert - e_lo) * cap + rank, e_loc * cap)

        buf = jnp.zeros((e_loc * cap + 1, d), dt)
        buf = buf.at[local_slot].set(tokens[flat_token[order]])
        buf = buf[: e_loc * cap].reshape(e_loc, cap, d)

        g_ = jnp.einsum("ecd,edf->ecf", buf, pl["w_gate"].astype(dt))
        u_ = jnp.einsum("ecd,edf->ecf", buf, pl["w_up"].astype(dt))
        h = jax.nn.silu(g_) * u_
        out_buf = jnp.einsum("ecf,efd->ecd", h, pl["w_down"].astype(dt))

        out_flat = out_buf.reshape(e_loc * cap, d)
        pair_out = jnp.where(
            mine[:, None],
            out_flat[jnp.minimum(local_slot, e_loc * cap - 1)], 0.0)
        partial = jnp.zeros((t, d), dt).at[flat_token[order]].add(
            pair_out * flat_gate[order][:, None].astype(dt))

        if cfg.num_shared_experts:
            sp = pl["shared"]  # hidden dim sharded over tp -> partial sums
            gsh = jnp.einsum("td,df->tf", tokens, sp["w_gate"].astype(dt))
            ush = jnp.einsum("td,df->tf", tokens, sp["w_up"].astype(dt))
            partial = partial + jnp.einsum(
                "tf,fd->td", jax.nn.silu(gsh) * ush, sp["w_down"].astype(dt))

        if tp:
            partial = jax.lax.psum(partial, tp)  # ONE bf16 psum
        return partial.reshape(x_loc.shape), aux

    return body(x, p)


def moe_ffn(p, cfg: ArchConfig, x):
    """x: (b, s, d) -> (b, s, d).  GShard-style grouped dispatch:
    groups over dp, experts over tp (EP); aux loss returned.

    With a mesh in context (and divisible dims) the shard_map fast path
    runs; the global-jit grouped form is the fallback/reference."""
    mesh = compat.get_abstract_mesh()
    if not mesh.empty:
        dp, tp, sizes = _sm_axes(mesh, x.shape[0])
        tp_ext = sizes.get(tp, 1) if tp else 1
        if cfg.num_experts % max(tp_ext, 1) == 0 and (
                not cfg.num_shared_experts
                or (cfg.moe_d_ff * cfg.num_shared_experts) % tp_ext == 0):
            return _moe_ffn_shard_map(p, cfg, x, mesh, dp, tp)
    b, s, d = x.shape
    dt = x.dtype
    e, k = cfg.num_experts, cfg.moe_top_k
    grp = _num_groups(b)
    tg = (b * s) // grp
    cap = _capacity(tg, cfg)
    tokens = x.reshape(grp, tg, d)
    tokens = maybe_shard(tokens, "dp", None, None)

    # routing in f32 for stable softmax
    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        p["router"].astype(jnp.float32))

    buf, info, aux = jax.vmap(
        lambda tok, lg: _group_dispatch(tok, lg, cfg, cap))(tokens, logits)
    # the reshard below IS the MoE all-to-all: (g over dp) -> (e over tp)
    buf = maybe_shard(buf, "dp", "tp", None, None)  # (g, e, cap, d)

    g_ = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    u_ = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(g_) * u_
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    out_buf = maybe_shard(out_buf, "dp", "tp", None, None)

    combined = jax.vmap(
        lambda ob, ki: _group_combine(ob, ki, tg, cap, cfg))(out_buf, info)
    combined = maybe_shard(combined, "dp", None, None)

    if cfg.num_shared_experts:
        sp = p["shared"]
        tok2 = tokens.reshape(grp * tg, d)
        gsh = jnp.einsum("td,df->tf", tok2, sp["w_gate"].astype(dt))
        ush = jnp.einsum("td,df->tf", tok2, sp["w_up"].astype(dt))
        shared = jnp.einsum("tf,fd->td", jax.nn.silu(gsh) * ush,
                            sp["w_down"].astype(dt))
        combined = combined + shared.reshape(grp, tg, d)

    return combined.reshape(b, s, d), jnp.mean(aux)
