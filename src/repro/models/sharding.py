"""Mesh-aware sharding helpers.

All model code expresses shardings with LOGICAL axis names ("dp" = batch
axes, "tp" = tensor axis); `maybe_shard` resolves them against whatever
mesh is in context (1-device CPU tests -> no-op; 16x16 pod -> data/model;
2x16x16 multi-pod -> pod+data/model).  This keeps the same model code
runnable from unit tests to the multi-pod dry-run.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

# logical -> candidate mesh axis names (first ones present in the mesh win)
LOGICAL = {
    "dp": ("pod", "data"),  # batch-parallel axes
    "tp": ("model",),  # tensor/expert-parallel axis
    "sp": ("model",),  # sequence axis in context-parallel layouts
}


def resolve_spec(*logical_axes) -> P:
    """Map logical axis names to a PartitionSpec for the current mesh."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty:
        return P()
    present = set(mesh.axis_names)
    out = []
    for ax in logical_axes:
        if ax is None:
            out.append(None)
            continue
        names = tuple(n for n in LOGICAL.get(ax, (ax,)) if n in present)
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return P(*out)


def maybe_shard(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint if a mesh is in context, else identity."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, resolve_spec(*logical_axes))


def shardable(dim: int, logical: str) -> bool:
    """True if `dim` divides evenly over the mesh extent of the logical
    axis (used to decide e.g. whether KV heads can be tensor-sharded)."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty:
        return False
    ext = 1
    present = dict(zip(mesh.axis_names, mesh.axis_sizes))
    for n in LOGICAL.get(logical, (logical,)):
        if n in present:
            ext *= present[n]
    return ext > 0 and dim % ext == 0
