"""Model zoo."""
