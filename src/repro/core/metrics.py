"""Convergence metrics (paper Sec. 5.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def subspace_error(v: jax.Array, v_star: jax.Array) -> jax.Array:
    """Normalized subspace error, Eq. (15):  1 - tr(U* P_t) / k.

    v, v_star: (n, k) bases (v need not be orthonormal — P uses a
    pseudo-inverse via QR as in Tang 2019 / Gemp et al. 2021a).
    """
    k = v_star.shape[1]
    q, _ = jnp.linalg.qr(v)  # orthonormal basis of span(v)
    # tr(V* V*^T Q Q^T) = ||V*^T Q||_F^2
    m = v_star.T @ q
    return 1.0 - jnp.sum(m * m) / k


def eigenvector_streak(v: jax.Array, v_star: jax.Array,
                       eps: float = 1e-2) -> jax.Array:
    """Longest consecutive run of matched eigenvectors (Gemp et al. 2021a).

    Eigenvector i counts as converged when |cos(angle(v_i, v*_i))| is
    within eps of 1 (sign-invariant).  Harsher than subspace error: the
    actual ORDERED eigenvectors must be recovered.
    """
    vn = v / jnp.maximum(jnp.linalg.norm(v, axis=0, keepdims=True), 1e-30)
    cos = jnp.abs(jnp.sum(vn * v_star, axis=0))
    ok = cos >= 1.0 - eps
    # longest prefix of ok
    return jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))


def panel_residual(v: jax.Array, av: jax.Array, eps: float = 1e-30) -> jax.Array:
    """Relative block-Rayleigh residual ||A V - V (V^T A V)||_F / ||A V||_F.

    Ground-truth-free convergence signal: 0 iff span(V) is an invariant
    subspace of A.  Used by the streaming service to decide per-session
    convergence and by warm-start to decide restart-vs-continue (columns
    of V are assumed orthonormal, as solver states maintain).
    """
    rayleigh = v.T @ av  # (k, k)
    r = av - v @ rayleigh
    return jnp.linalg.norm(r) / jnp.maximum(jnp.linalg.norm(av), eps)


def operator_residual(matvec, v: jax.Array) -> jax.Array:
    """``panel_residual`` of a panel under an operator: one operator
    application + the block-Rayleigh residual.  The single residual
    evaluation every solve program (one-shot, streaming ticks, sharded,
    warm reconvergence) ends its compiled loop with."""
    return panel_residual(v, matvec(v))


def ground_truth_bottom_k(l_mat: jax.Array, k: int, drop_trivial: bool = False):
    """Bottom-k eigenpairs of dense L via eigh (ascending).

    drop_trivial skips the all-ones nullvector (lambda_1 = 0) when the
    clustering only cares about the Fiedler directions.
    """
    lam, v = jnp.linalg.eigh(l_mat)
    s = 1 if drop_trivial else 0
    return lam[s: s + k], v[:, s: s + k]
