"""Iterative/stochastic top-k SVD solvers (paper Sec. 5.1).

Two representative solvers from the paper:
  * Oja's algorithm (Shamir 2015): gradient ascent on the trace objective
    with QR retraction.
  * mu-EigenGame / "EigenGame Unloaded" (Gemp et al. 2021b): per-vector
    utility ascent with Riemannian projection; penalties use v_j (not
    A v_j), which is what makes unbiased minibatch estimates possible.

Both consume an OPERATOR ``matvec: (n,k) -> (n,k)`` computing A @ V where
A is the (reversed, transformed) Laplacian — exact, series-approximated,
or stochastic.  The solver itself is agnostic; that separation is the
paper's architecture: transformation and estimation happen inside the
operator, convergence happens here.

Solvers find the TOP-k of A; the Eq. (8) reversal makes those the
bottom-k of L.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

MatVec = Callable[[jax.Array], jax.Array]
# stochastic operators additionally take a PRNG key
StochMatVec = Callable[[jax.Array, jax.Array], jax.Array]


class SolverState(NamedTuple):
    v: jax.Array  # (n, k) current estimate, orthonormal columns
    step: jax.Array  # scalar int32


def init_state(key: jax.Array, n: int, k: int, dtype=jnp.float32) -> SolverState:
    v0 = jax.random.normal(key, (n, k), dtype=dtype)
    q, _ = jnp.linalg.qr(v0)
    return SolverState(v=q, step=jnp.zeros((), jnp.int32))


def init_from_panel(v: jax.Array) -> SolverState:
    """Warm-start hook: seed a solver from an existing (n, k) panel.

    Orthonormalizes via QR (with the same sign fix as `oja_step`), so a
    previous session's converged eigenvectors — or a first-order
    incrementally-updated panel — can seed the next solve directly.
    """
    q, r = jnp.linalg.qr(v)
    sign = jnp.sign(jnp.diagonal(r))
    sign = jnp.where(sign == 0, 1.0, sign)
    return SolverState(v=q * sign[None, :], step=jnp.zeros((), jnp.int32))


def oja_step(state: SolverState, av: jax.Array, lr: float) -> SolverState:
    """V <- QR(V + lr * A V).  One Oja update with QR retraction."""
    v = state.v + lr * av
    q, r = jnp.linalg.qr(v)
    # fix QR sign ambiguity for determinism (diag(R) >= 0)
    sign = jnp.sign(jnp.diagonal(r))
    sign = jnp.where(sign == 0, 1.0, sign)
    return SolverState(v=q * sign[None, :], step=state.step + 1)


def mu_eg_step(state: SolverState, av: jax.Array, lr: float) -> SolverState:
    """One mu-EigenGame (unloaded) update.

    grad_i = A v_i - sum_{j<i} <v_i, A v_j> v_j        (utility gradient)
    r_i    = grad_i - <v_i, grad_i> v_i                (sphere projection)
    v_i   <- normalize(v_i + lr * r_i)
    """
    v = state.v
    vav = v.T @ av  # (k, k): [i, j] = <v_i, A v_j>
    # strictly-lower mask: penalties from parents j < i
    k = v.shape[1]
    lower = jnp.tril(jnp.ones((k, k), v.dtype), k=-1)
    # penalty_i = sum_{j<i} vav[i, j] * v_j  -> columns: V @ (lower * vav)^T
    penalties = v @ (lower * vav).T
    grad = av - penalties
    grad = grad - v * jnp.sum(v * grad, axis=0, keepdims=True)  # Riemannian
    vn = v + lr * grad
    vn = vn / jnp.maximum(jnp.linalg.norm(vn, axis=0, keepdims=True), 1e-30)
    return SolverState(v=vn, step=state.step + 1)


def mu_eg_step_fused(state: SolverState, av: jax.Array, lr: float,
                     *, interpret: bool = False) -> SolverState:
    """mu-EigenGame step via the fused Pallas kernels: the update is the
    linear combination V' = (V @ M1 + AV @ M2) * colscale with k x k
    coefficient matrices from the gram of [V | AV]
    (repro.kernels.eg_update.coefficient_matrices), so the whole step is
    TWO panel passes (gram + mix) instead of ~7 elementwise/matmul
    passes.  Same math as :func:`mu_eg_step` — the segment oracle."""
    from repro.kernels.eg_update import ops as eg_ops

    v = eg_ops.mu_eg_update(state.v, av, lr, interpret=interpret)
    return SolverState(v=v, step=state.step + 1)


def panel_gram2k(v: jax.Array, av: jax.Array) -> jax.Array:
    """2k x 2k gram of the stacked panel [V | AV] — the ONLY panel
    reduction the mu-EG step needs (see :func:`mu_eg_step_from_gram`).

    Row-decomposable: for any partition of the rows into disjoint
    slices, the full gram is the SUM of the per-slice grams.  That is
    what lets a model-sharded tick compute it per shard on owned rows
    and psum the contributions fused with the panel assembly."""
    x = jnp.concatenate([v, av], axis=1)
    return x.T @ x


def mu_eg_step_from_gram(state: SolverState, av: jax.Array,
                         gram: jax.Array, lr) -> SolverState:
    """mu-EG update from a PRECOMPUTED 2k x 2k gram of [V | AV].

    Same math as :func:`mu_eg_step`: the update is the linear mix
    V' = (V @ M1 + AV @ M2) * colscale with coefficient matrices derived
    from the gram alone (repro.kernels.eg_update.ref), so once ``gram``
    is known the step is ROW-LOCAL — ``state.v``/``av`` may be any row
    slice of the panel (a model shard's owned rows) as long as ``gram``
    is the global gram.  This is the fused-collective hook of the
    model-sharded tick: per-shard grams psum together with the panel
    assembly, then every shard mixes its own rows with zero further
    communication.
    """
    from repro.kernels.eg_update import ref as eg_ref

    k = state.v.shape[1]
    m1, m2, colscale = eg_ref.coefficient_matrices(gram, k, lr)
    vn = (state.v @ m1 + av @ m2) * colscale[None, :]
    return SolverState(v=vn, step=state.step + 1)


STEP_FNS = {"oja": oja_step, "mu_eg": mu_eg_step}


def make_step_fn(method: str, backend: str = "auto"):
    """Solver step on the selected backend (repro.core.backend).

    ``mu_eg`` + pallas selects the fused two-pass kernel step; ``oja``
    has no kernel form (its QR retraction dominates) and stays on the
    segment implementation for every backend.
    """
    from repro.core import backend as backend_mod

    if method == "mu_eg" and backend_mod.resolve_backend(backend) == "pallas":
        return functools.partial(
            mu_eg_step_fused, interpret=backend_mod.kernel_interpret())
    return STEP_FNS[method]


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    method: str = "mu_eg"  # "oja" | "mu_eg"
    lr: float = 1e-3
    steps: int = 1000
    eval_every: int = 10
    k: int = 8
    seed: int = 0
    backend: str = "auto"  # solver-step kernels: auto | segment | pallas


class Trace(NamedTuple):
    """Metrics recorded every eval_every steps."""
    steps: jax.Array  # (T,)
    subspace_error: jax.Array  # (T,)
    streak: jax.Array  # (T,)


def run_solver(
    operator: MatVec | StochMatVec,
    n: int,
    cfg: SolverConfig,
    v_star: jax.Array | None = None,
    stochastic: bool = False,
    init_v: jax.Array | None = None,
) -> tuple[SolverState, Trace]:
    """Run a solver, recording metrics against ground truth v_star.

    Thin wrapper over :func:`repro.core.program.run_program` — the
    unified solve loop shared with the streaming tick programs and the
    distributed solves.  The whole run is one jitted scan over eval
    chunks, so Python overhead is O(1) in the number of steps.  `init_v`
    warm-starts from an (n, k) panel (orthonormalized via
    `init_from_panel`) instead of the default random init — the
    streaming service's reconvergence path.
    """
    from repro.core import program  # deferred: program builds on solvers

    return program.run_program(operator, n, cfg, v_star=v_star,
                               stochastic=stochastic, init_v=init_v)


def steps_to_tolerance(trace: Trace, tol: float) -> int:
    """First recorded step at which subspace error <= tol (or -1)."""
    err = np.asarray(trace.subspace_error)
    idx = np.nonzero(err <= tol)[0]
    return int(np.asarray(trace.steps)[idx[0]]) if len(idx) else -1


def steps_to_streak(trace: Trace, k: int) -> int:
    """First recorded step with a full-k eigenvector streak (or -1)."""
    st = np.asarray(trace.streak)
    idx = np.nonzero(st >= k)[0]
    return int(np.asarray(trace.steps)[idx[0]]) if len(idx) else -1
