"""jit-friendly k-means (Lloyd's + k-means++ init, vmapped restarts).

Used for the final "hard clustering" step of spectral clustering
(paper Sec. 1/2.1).  Pure jnp so the whole clustering pipeline jits.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, d)
    labels: jax.Array  # (n,)
    inertia: jax.Array  # scalar


def _plusplus_init(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding."""
    n = x.shape[0]
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n)
    centroids = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        key, centroids = carry
        d2 = jnp.min(
            jnp.sum((x[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
            + jnp.where(jnp.arange(k)[None, :] >= i, jnp.inf, 0.0),
            axis=1,
        )
        key, sub = jax.random.split(key)
        probs = d2 / jnp.maximum(jnp.sum(d2), 1e-30)
        idx = jax.random.choice(sub, x.shape[0], p=probs)
        return key, centroids.at[i].set(x[idx])

    _, centroids = jax.lax.fori_loop(1, k, body, (key, centroids))
    return centroids


def _lloyd(x: jax.Array, centroids: jax.Array, iters: int) -> KMeansResult:
    k = centroids.shape[0]

    def body(_, c):
        d2 = jnp.sum((x[:, None, :] - c[None, :, :]) ** 2, axis=-1)
        labels = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(labels, k, dtype=x.dtype)  # (n, k)
        counts = jnp.sum(onehot, axis=0)  # (k,)
        sums = onehot.T @ x  # (k, d)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1)[:, None], c)
        return new

    centroids = jax.lax.fori_loop(0, iters, body, centroids)
    d2 = jnp.sum((x[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    labels = jnp.argmin(d2, axis=1)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return KMeansResult(centroids=centroids, labels=labels, inertia=inertia)


@functools.partial(jax.jit, static_argnames=("k", "iters", "restarts"))
def kmeans(key: jax.Array, x: jax.Array, k: int,
           iters: int = 25, restarts: int = 8) -> KMeansResult:
    """Best-of-`restarts` k-means (vmapped)."""
    keys = jax.random.split(key, restarts)
    inits = jax.vmap(lambda kk: _plusplus_init(kk, x, k))(keys)
    results = jax.vmap(lambda c: _lloyd(x, c, iters))(inits)
    best = jnp.argmin(results.inertia)
    return KMeansResult(
        centroids=results.centroids[best],
        labels=results.labels[best],
        inertia=results.inertia[best],
    )


def cluster_agreement(labels: jax.Array, truth: jax.Array, k: int) -> jax.Array:
    """Greedy-matching clustering accuracy in [0, 1] (label-permutation
    invariant, adequate for well-separated test graphs)."""
    conf = jnp.zeros((k, k))
    conf = conf.at[labels, truth].add(1.0)
    # greedy assignment: repeatedly take the max cell
    def body(_, carry):
        conf, acc = carry
        idx = jnp.argmax(conf)
        i, j = idx // k, idx % k
        acc = acc + conf[i, j]
        conf = conf.at[i, :].set(-1.0).at[:, j].set(-1.0)
        return conf, acc
    _, acc = jax.lax.fori_loop(0, k, body, (conf, 0.0))
    return acc / labels.shape[0]
