"""Stochastic parallel estimation of Laplacian powers via random walks on
the edge incidence graph (paper Sec. 4.3, Eqs. 12-14).

Identity (Eq. 12):   L^l = sum_{chains c in E^l} alpha_c x_{e_1} x_{e_l}^T
where alpha_c = prod_j x_{e_j}^T x_{e_{j+1}} is nonzero exactly when
consecutive edges are incident, i.e. when (e_1..e_l) is a walk on the
edge incidence graph (self loops included; Table 1 gives the factor
values in {2, +-1}).

Sampling: a walk is drawn by picking a uniform edge then stepping to a
uniform incident edge l-1 times; its probability is
p_l = (1/|E|) prod_{i<l} 1/deg(e_i)  (Eq. 13 — the final edge needs no
step probability).  Two unbiased estimators are provided:

  * ``rejection`` (paper-faithful): accept with prob p_min / p_l,
    p_min = (2 deg* - 1)^{-(l-1)} / |E| (Eq. 14); every chain then occurs
    w.p. exactly p_min, and
        L^l  =  E[ 1{acc} alpha_c x_{e_1} x_{e_l}^T ] / p_min.
  * ``importance`` (beyond-paper; the paper's stated future work of
    "improving upon the simple rejection sampling scheme"): weight each
    drawn walk by alpha_c / p_l(c) — a Horvitz-Thompson estimator with
    acceptance probability 1.  Strictly lower variance (Rao-Blackwell of
    the accept coin) and no wasted walkers.

TPU adaptation: walks are shape-static (lax.scan over l steps, vmap over
walkers, shard_map over devices); rejection becomes masking so the SPMD
program never data-depends on acceptance.  A single batch of length-l
walks yields unbiased estimates of ALL powers i <= l simultaneously
(linearity of expectation, paper Sec. 4.3): prefix products alpha_{1:i}
with endpoints (e_1, e_i) estimate L^i.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.laplacian import EdgeIncidence, EdgeList


class WalkBatch(NamedTuple):
    """Batch of length-l walks with per-prefix statistics.

    For walker w and prefix length i (1-indexed power L^i uses prefix of
    i edges => i-1 steps):
      first_edge[w]      — e_1
      edge_at[w, i]      — e_{i+1} after i steps (so edge_at[w, 0] = e_1)
      alpha[w, i]        — prod of the first i incidence inner products
                           (alpha[w, 0] = 1)
      logp[w, i]         — log p of the length-(i+1) prefix walk (Eq. 13)
    """

    first_edge: jax.Array  # (W,) int32
    edge_at: jax.Array  # (W, l) int32
    alpha: jax.Array  # (W, l) float32
    logp: jax.Array  # (W, l) float32


def sample_walks(key: jax.Array, inc: EdgeIncidence, num_walkers: int,
                 length: int) -> WalkBatch:
    """Draw `num_walkers` independent length-`length` walks (vmapped)."""
    e = inc.nbrs.shape[0]

    def one_walk(k):
        k0, k1 = jax.random.split(k)
        e0 = jax.random.randint(k0, (), 0, e)
        logp0 = -jnp.log(float(e))

        def step(carry, kk):
            cur, alpha, logp = carry
            d = inc.deg[cur]
            slot = jax.random.randint(kk, (), 0, d)
            nxt = inc.nbrs[cur, slot]
            alpha = alpha * inc.ip[cur, slot]
            logp = logp - jnp.log(d.astype(jnp.float32))
            return (nxt, alpha, logp), (nxt, alpha, logp)

        ks = jax.random.split(k1, length - 1)
        _, (edges, alphas, logps) = jax.lax.scan(
            step, (e0, jnp.float32(1.0), logp0), ks)
        edge_at = jnp.concatenate([e0[None], edges])
        alpha = jnp.concatenate([jnp.ones((1,), jnp.float32), alphas])
        logp = jnp.concatenate([jnp.full((1,), logp0), logps])
        return WalkBatch(first_edge=e0, edge_at=edge_at, alpha=alpha, logp=logp)

    keys = jax.random.split(key, num_walkers)
    return jax.vmap(one_walk)(keys)


def _accumulate_rank1(out, g: EdgeList, e_first, e_last, coeff, v):
    """out += sum_w coeff[w] * x_{e_first[w]} (x_{e_last[w]}^T v).

    x_e has two nonzeros (+1 at src, -1 at dst) so each term is a 2-row
    scatter of the 2-row gather (x_last^T v) — O(W k), never n x n.
    """
    xv = v[g.src[e_last]] - v[g.dst[e_last]]  # (W, k) = x_{e_l}^T v rows
    contrib = coeff[:, None] * xv  # (W, k)
    out = out.at[g.src[e_first]].add(contrib)
    out = out.at[g.dst[e_first]].add(-contrib)
    return out


def estimate_power_matvec(
    walks: WalkBatch, g: EdgeList, inc: EdgeIncidence, power: int,
    v: jax.Array, mode: str = "importance", key: jax.Array | None = None,
) -> jax.Array:
    """Unbiased estimate of L^power @ v from a walk batch (power >= 1).

    Uses the length-(power) prefixes of the walks.  `mode`:
      'importance' — HT weights alpha/p (no rejection; lower variance)
      'rejection'  — paper's Eq. 14 accept-coin, implemented as masking
    """
    i = power - 1  # prefix index: i steps
    w = walks.first_edge.shape[0]
    e_last = walks.edge_at[:, i]
    alpha = walks.alpha[:, i]
    logp = walks.logp[:, i]
    if mode == "importance":
        coeff = alpha * jnp.exp(-logp) / w
    elif mode == "rejection":
        if key is None:
            raise ValueError("rejection mode needs a key for the accept coin")
        log_pmin = -power * jnp.log(jnp.float32(inc.deg_star_inc)) \
            - jnp.log(jnp.float32(g.num_edges))
        # accept w.p. p_min / p_l  (<= 1 by construction of deg*_inc)
        p_acc = jnp.exp(jnp.minimum(log_pmin - logp, 0.0))
        accept = jax.random.uniform(key, (w,)) < p_acc
        coeff = jnp.where(accept, alpha, 0.0) * jnp.exp(-log_pmin) / w
    else:
        raise ValueError(f"unknown mode {mode!r}")
    out = jnp.zeros_like(v)
    return _accumulate_rank1(out, g, walks.first_edge, e_last, coeff, v)


def walk_polynomial_operator(
    g: EdgeList,
    inc: EdgeIncidence,
    coeffs: tuple[float, ...],
    lambda_star: float,
    num_walkers: int,
    mode: str = "importance",
):
    """op(key, V) -> (lambda* I - P(L)) V with P(L) = sum_i coeffs[i] L^i
    estimated from ONE shared batch of length-(deg) walks — the paper's
    'single walk estimates all shorter powers' trick (Sec. 4.3).

    Intended for low-degree polynomials where walk variance is
    manageable; high-degree series should use the minibatch operator.
    """
    deg = len(coeffs) - 1
    if deg < 1:
        raise ValueError("need degree >= 1")

    def op(key: jax.Array, v: jax.Array) -> jax.Array:
        kw, kc = jax.random.split(key)
        walks = sample_walks(kw, inc, num_walkers, max(deg, 2))
        acc = coeffs[0] * v
        for p in range(1, deg + 1):
            est = estimate_power_matvec(
                walks, g, inc, p, v, mode=mode,
                key=jax.random.fold_in(kc, p) if mode == "rejection" else None)
            acc = acc + coeffs[p] * est
        return lambda_star * v - acc

    return op


# ---------------------------------------------------------------------------
# Dense-estimate helpers (for tests: estimate L^l itself, not L^l v).
# ---------------------------------------------------------------------------

def estimate_power_dense(
    walks: WalkBatch, g: EdgeList, inc: EdgeIncidence, power: int,
    n: int, mode: str = "importance", key: jax.Array | None = None,
) -> jax.Array:
    """Materialize the L^power estimate as an (n, n) matrix (test-sized
    graphs only) by applying the estimator to I."""
    eye = jnp.eye(n, dtype=jnp.float32)
    return estimate_power_matvec(walks, g, inc, power, eye, mode=mode, key=key)


def lowdeg_negexp_coeffs(degree: int, rho: float, tau: float = 1.0
                         ) -> tuple[float, ...]:
    """Power-basis coefficients of a degree-`degree` Chebyshev fit of
    -e^{-tau x} on [0, rho].  Low degree only (<= ~10): the power basis is
    exact what the walk estimator needs (one coefficient per L^i), and at
    such degrees the basis conversion is numerically safe in float64.
    """
    import numpy as np
    j = np.arange(degree + 1)
    t = np.cos(np.pi * (j + 0.5) / (degree + 1))
    x = 0.5 * rho * (t + 1.0)
    f = -np.exp(-tau * x)
    v = np.vander(x, degree + 1, increasing=True)
    coeffs, *_ = np.linalg.lstsq(v, f, rcond=None)
    return tuple(float(c) for c in coeffs)
