"""Matvec backend selection: segment gather/scatter vs Pallas kernels.

Every solver-facing operator constructor (``operators.edge_matvec``,
``operators.minibatch_operator``, ``operators.planned_operator``,
``operators.series_operator`` via its fused-step hook, the streaming
service's compiled tick programs, and
``distributed.sharded_laplacian_matvec``) routes its inner Laplacian
matvec through this layer:

  * ``backend="segment"`` — the pure-jnp ``at[].add`` gather/scatter in
    :mod:`repro.core.laplacian`.  Portable; the XLA scatter serializes
    on TPU.
  * ``backend="pallas"`` — the TPU kernels in :mod:`repro.kernels`.
    On small graphs (n <= ``ONE_HOT_NODE_LIMIT``) the one-hot incidence
    SpMM holds the whole (n, k) panel in VMEM; beyond that the
    NODE-BLOCKED kernel is used, whose host-side layout
    (:func:`build_node_blocking`) buckets half-edges by destination
    node-block so VMEM only ever holds a (block_n, k) panel slice —
    that is the VMEM blocking contract: per grid step the kernel touches
    one (block_n, k) output slice, one (block_e, k) pre-gathered source
    chunk, and a (block_e, block_n) local one-hot, independent of n.
  * ``backend="auto"`` — pallas on TPU, segment elsewhere.

Off-TPU, pallas kernels run in INTERPRET mode (``kernel_interpret()``),
which is correct but slow — it exists so the equivalence tests and CPU
CI exercise the exact kernel code paths.  Force a backend by passing
``backend="segment"|"pallas"`` to any operator constructor, or set the
``REPRO_BACKEND`` environment variable to override ``"auto"``.

Fused series steps: the factories here return, alongside the plain
matvec, a ``fused_step(u, alpha, beta) -> alpha * L u + beta * u`` that
folds one series-recurrence AXPY into the SpMM epilogue (see
``SpectralSeries.apply_fused``).  For the segment backend the fused
step is ``None`` and series fall back to their classic recurrences.
"""
from __future__ import annotations

import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import laplacian as lap
from repro.kernels.edge_spmm import ops as es_ops
from repro.kernels.edge_spmm.ops import (  # noqa: F401  (re-exported API)
    ModelShardedBlocking,
    NodeBlocking,
    ShardedNodeBlocking,
    build_model_sharded_blocking,
    build_node_blocking,
    build_sharded_node_blocking,
)

MatVec = Callable[[jax.Array], jax.Array]
# fused_step(u, alpha, beta) -> alpha * (L @ u) + beta * u
FusedStep = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]

BACKENDS = ("auto", "segment", "pallas")

# Largest n the one-hot kernel may hold as a full (block_e, n) incidence
# block + (n, k) panel in VMEM; past it the node-blocked layout is used.
ONE_HOT_NODE_LIMIT = 4096

# Default node-block size for auto-built blockings: 512 rows x 128 lanes
# x 4 B = 256 kB per panel slice — comfortably inside ~16 MB VMEM next
# to the (block_e, block_n) one-hot and the gathered chunk.
DEFAULT_BLOCK_N = 512


def is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_interpret() -> bool:
    """Pallas interpret mode: on for every non-TPU backend (tests/CI)."""
    return not is_tpu()


def resolve_backend(backend: str = "auto") -> str:
    """'auto' -> 'pallas' on TPU, 'segment' elsewhere (overridable via
    the REPRO_BACKEND environment variable)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    if backend == "auto":
        env = os.environ.get("REPRO_BACKEND", "")
        if env:
            if env not in ("segment", "pallas"):
                raise ValueError(
                    f"REPRO_BACKEND={env!r}: expected 'segment' or 'pallas'")
            return env
        return "pallas" if is_tpu() else "segment"
    return backend


def resolve_for_arrays(backend: str, num_nodes: int) -> str:
    """Backend for call sites WITHOUT a precomputed node blocking
    (minibatch draws, probes, per-shard matvecs): pallas there means the
    one-hot kernel, so past its VMEM node limit the resolution degrades
    to segment instead of blowing VMEM.  THE single copy of that policy
    — blocking-aware call sites use ``resolve_backend`` directly."""
    b = resolve_backend(backend)
    if b == "pallas" and num_nodes > ONE_HOT_NODE_LIMIT:
        return "segment"
    return b


def blocking_for(g: lap.EdgeList, *, block_n: int | None = None,
                 block_e: int = 128) -> NodeBlocking:
    """Host-side node-blocked layout of an EdgeList (concrete arrays)."""
    return build_node_blocking(
        g.src, g.dst, g.weight, g.num_nodes,
        block_n=block_n or DEFAULT_BLOCK_N, block_e=block_e)


def sharded_blocking_for(g: lap.EdgeList, num_shards: int,
                         *, block_n: int | None = None,
                         block_e: int = 128) -> ShardedNodeBlocking:
    """Per-shard node-blocked layouts of a mesh-padded EdgeList — the
    scalable layout for ``distributed.sharded_blocked_matvec`` (the
    sharded pallas path past ``ONE_HOT_NODE_LIMIT``)."""
    return build_sharded_node_blocking(
        g.src, g.dst, g.weight, g.num_nodes, num_shards,
        block_n=block_n or DEFAULT_BLOCK_N, block_e=block_e)


def model_blocking_for(g: lap.EdgeList, num_shards: int,
                       *, block_n: int | None = None,
                       block_e: int = 128) -> ModelShardedBlocking:
    """Destination-aligned per-shard layouts for PANEL sharding — shard
    ``s`` owns rows ``[s * R, (s + 1) * R)`` of the (n, k) panel and all
    half-edges destined there (``program.build_tick_model_sharded``'s
    layout; works for both the kernel and segment row computations)."""
    return build_model_sharded_blocking(
        g.src, g.dst, g.weight, g.num_nodes, num_shards,
        block_n=block_n or DEFAULT_BLOCK_N, block_e=block_e)


def _needs_blocking(num_nodes: int) -> bool:
    return num_nodes > ONE_HOT_NODE_LIMIT


def fused_step_fn(g: lap.EdgeList, backend: str = "auto",
                  blocking: NodeBlocking | None = None) -> FusedStep | None:
    """fused_step(u, alpha, beta) = alpha * L u + beta * u, or None.

    The pallas path picks the one-hot kernel for small n and the
    node-blocked kernel otherwise (building — host-side, so ``g`` must
    hold concrete arrays — and capturing the blocking when none is
    supplied).  Segment returns None: callers then use the plain matvec
    recurrences, whose subtract-after-matvec ordering is bitwise
    identical to an explicit AXPY.
    """
    if resolve_backend(backend) == "segment":
        return None
    interp = kernel_interpret()
    if blocking is None and _needs_blocking(g.num_nodes):
        blocking = blocking_for(g)
    if blocking is not None:
        def fused(u, alpha, beta):
            return es_ops.edge_spmm_blocked(
                blocking, u, alpha=alpha, beta=beta, interpret=interp)
        return fused

    def fused(u, alpha, beta):
        return es_ops.edge_spmm(g.src, g.dst, g.weight, u,
                                alpha=alpha, beta=beta, interpret=interp)
    return fused


def laplacian_matvec_fn(g: lap.EdgeList, backend: str = "auto",
                        blocking: NodeBlocking | None = None) -> MatVec:
    """V -> L @ V on the resolved backend (V may be (n,) or (n, k))."""
    fused = fused_step_fn(g, backend, blocking)
    if fused is None:
        return functools.partial(lap.laplacian_matvec, g)
    return lambda v: fused(v, 1.0, 0.0)


def edge_arrays_matvec_fn(src: jax.Array, dst: jax.Array, weight: jax.Array,
                          backend: str = "auto",
                          *, num_nodes: int | None = None,
                          interpret: bool | None = None) -> MatVec:
    """Raw-array matvec factory for jit-internal call sites (spectral
    probes, minibatch draws, per-shard matvecs) where no host-side
    blocking can be built: the pallas path uses the one-hot kernel, and
    when ``num_nodes`` is given the ``resolve_for_arrays`` guard drops
    to segment past the kernel's VMEM node limit."""
    b = (resolve_for_arrays(backend, num_nodes) if num_nodes is not None
         else resolve_backend(backend))
    if b == "segment":
        return functools.partial(lap.edge_matvec_arrays, src, dst, weight)
    interp = kernel_interpret() if interpret is None else interpret
    return lambda v: es_ops.edge_spmm(src, dst, weight, v, interpret=interp)
