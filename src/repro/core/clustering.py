"""End-to-end spectral clustering with SPED (paper Secs. 1-2, 5).

Pipeline:  edges -> L -> [spectrum transform + Eq.8 reversal] -> top-k
solver (Oja / mu-EG) -> bottom-k eigenvector embedding -> k-means.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import kmeans as km
from repro.core import laplacian as lap
from repro.core import metrics, operators, series, solvers


@dataclasses.dataclass(frozen=True)
class ClusteringConfig:
    num_clusters: int = 4
    extra_eigvecs: int = 1  # compute k + extra for a stable embedding
    # key into series factories / 'identity' / 'auto' (probe the spectrum
    # and let repro.spectral.plan_dilation pick family+degree+scale)
    transform: str = "limit_neg_exp"
    degree: int = 251
    auto_scale: bool = True  # pre-scale L to a target radius (beyond-paper, Fig.4 fix)
    # effective decay strength tau: with auto_scale, the transform acts like
    # -e^{-tau * lam / rho}, improving the Sec.3 ratio by ~tau while staying
    # within the series' accuracy region (tau^2 << degree for limit series).
    dilation_strength: float = 8.0
    estimation: str = "exact_edges"  # exact_edges | minibatch | walks
    batch_edges: int = 1024
    num_walkers: int = 4096
    solver: solvers.SolverConfig = dataclasses.field(
        default_factory=solvers.SolverConfig)
    drop_trivial: bool = True  # skip the all-ones nullvector in the embedding
    kmeans_restarts: int = 8
    seed: int = 0
    # matvec/solver-step kernels (repro.core.backend): auto | segment |
    # pallas.  auto = pallas on TPU, segment elsewhere.
    backend: str = "auto"


def build_series(cfg: ClusteringConfig, rho_ub: float) -> series.SpectralSeries:
    scale = cfg.dilation_strength / max(rho_ub, 1e-30) if cfg.auto_scale else 1.0
    if cfg.transform == "identity":
        # no transform; reversal needs lambda* > rho(L) (Eq. 8)
        return series.with_lambda_star(series.identity_series(), rho_ub * 1.01)
    if cfg.transform == "limit_neg_exp":
        return series.limit_neg_exp(cfg.degree, scale=scale)
    if cfg.transform == "taylor_neg_exp":
        return series.taylor_neg_exp(cfg.degree)
    if cfg.transform == "taylor_log":
        return series.taylor_log(cfg.degree)
    if cfg.transform == "cheb_neg_exp":
        tau = cfg.dilation_strength / rho_ub if cfg.auto_scale else 1.0
        return series.cheb_neg_exp(cfg.degree, rho=rho_ub, tau=tau)
    if cfg.transform == "cheb_log":
        return series.cheb_log(cfg.degree, rho=rho_ub)
    raise ValueError(f"unknown transform {cfg.transform!r}")


def spectral_cluster(
    g: lap.EdgeList, cfg: ClusteringConfig,
    v_star: jax.Array | None = None,
):
    """Run the full pipeline.  Returns (labels, info dict)."""
    rho_ub = float(lap.spectral_radius_upper_bound(g))
    k = cfg.num_clusters + cfg.extra_eigvecs + (1 if cfg.drop_trivial else 0)
    plan = None
    if cfg.transform == "auto" and cfg.estimation != "walks":
        from repro import spectral  # deferred: spectral builds on core

        _, plan = spectral.probe_and_plan(
            g, k=k, key=jax.random.PRNGKey(cfg.seed + 3), budget=cfg.degree,
            backend=cfg.backend)
        s = spectral.series_from_plan(plan)
        # solver steps are not scale-invariant; renormalize the user's
        # lr (tuned for unit-scale series) to the planned operator's
        # scale.
        cfg = dataclasses.replace(
            cfg, solver=dataclasses.replace(
                cfg.solver, lr=plan.suggested_lr(cfg.solver.lr)))
    elif cfg.transform == "auto":
        # the walks estimator builds its own low-degree operator below
        # and ignores any planned series — don't pay the probe for a
        # plan that would be discarded (s only supplies info["series"])
        s = series.with_lambda_star(series.identity_series(), rho_ub * 1.01)
    else:
        s = build_series(cfg, rho_ub)
    scfg = dataclasses.replace(cfg.solver, k=k, seed=cfg.seed,
                               backend=cfg.backend)

    if cfg.estimation == "exact_edges":
        op = operators.edge_series_operator(g, s, backend=cfg.backend)
        stochastic = False
    elif cfg.estimation == "minibatch":
        op = operators.minibatch_operator(g, s, cfg.batch_edges,
                                          backend=cfg.backend)
        stochastic = True
    elif cfg.estimation == "walks":
        from repro.core import walks as walks_mod
        inc = lap.build_edge_incidence(g)
        # walk estimator variance grows with degree; use a LOW-degree
        # power-basis fit of the same spectral map (beyond-paper; the
        # paper itself only runs walks conceptually).
        deg = min(cfg.degree, 6)
        tau = cfg.dilation_strength / rho_ub if cfg.auto_scale else 1.0
        coeffs = walks_mod.lowdeg_negexp_coeffs(deg, rho_ub, tau)
        op = walks_mod.walk_polynomial_operator(
            g, inc, coeffs, lambda_star=0.0, num_walkers=cfg.num_walkers)
        stochastic = True
    else:
        raise ValueError(cfg.estimation)

    if v_star is None and g.num_nodes <= 4096:
        l_dense = lap.laplacian_dense(g)
        _, v_star = metrics.ground_truth_bottom_k(l_dense, k)

    state, trace = solvers.run_solver(
        op, g.num_nodes, scfg, v_star=v_star, stochastic=stochastic)

    start = 1 if cfg.drop_trivial else 0
    embedding = state.v[:, start: start + cfg.num_clusters]
    # row-normalize the embedding (standard spectral clustering practice)
    norms = jnp.linalg.norm(embedding, axis=1, keepdims=True)
    embedding = embedding / jnp.maximum(norms, 1e-12)
    result = km.kmeans(
        jax.random.PRNGKey(cfg.seed + 1), embedding, cfg.num_clusters,
        restarts=cfg.kmeans_restarts)
    info = {
        "trace": trace,
        "series": s.name,
        "rho_ub": rho_ub,
        "eigvecs": state.v,
        "embedding": embedding,
        "plan": plan,
    }
    return result.labels, info


def exact_cluster_reference(g: lap.EdgeList, num_clusters: int, seed: int = 0):
    """Ground-truth pipeline via dense eigh — the oracle for tests."""
    l_dense = lap.laplacian_dense(g)
    _, v = metrics.ground_truth_bottom_k(l_dense, num_clusters, drop_trivial=True)
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=1, keepdims=True), 1e-12)
    res = km.kmeans(jax.random.PRNGKey(seed + 1), v, num_clusters)
    return res.labels
