"""Graph Laplacian construction and matrix-free operators.

The paper (Sec. 2) works with L = D - A = X^T X where X is the edge
incidence matrix: row x_e for edge e=(i,j), i<j, has +1 at index i and
-1 at index j.  Weighted graphs use L = X^T W X.

Everything here is jnp and jit-friendly.  Edge lists are int32 arrays of
shape (E, 2) with column 0 < column 1 (canonicalized on construction).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EdgeList(NamedTuple):
    """Canonical edge representation: src < dst per row, optional weights."""

    src: jax.Array  # (E,) int32, src < dst
    dst: jax.Array  # (E,) int32
    weight: jax.Array  # (E,) float32
    num_nodes: int  # static

    @property
    def num_edges(self) -> int:
        return self.src.shape[0]


def make_edge_list(edges, num_nodes: int, weights=None) -> EdgeList:
    """Canonicalize an (E, 2) array of node pairs into an EdgeList."""
    edges = jnp.asarray(edges, dtype=jnp.int32)
    src = jnp.minimum(edges[:, 0], edges[:, 1])
    dst = jnp.maximum(edges[:, 0], edges[:, 1])
    if weights is None:
        weights = jnp.ones((edges.shape[0],), dtype=jnp.float32)
    else:
        weights = jnp.asarray(weights, dtype=jnp.float32)
    return EdgeList(src=src, dst=dst, weight=weights, num_nodes=int(num_nodes))


def pad_edge_list(g: EdgeList, capacity: int) -> EdgeList:
    """Pad to a fixed edge capacity with inert zero-weight slots.

    Zero weight makes padded slots contribute nothing to any edge-wise
    computation (matvec, degrees, dense L), so every operator in this
    module — and the sharded matvecs in :mod:`repro.core.distributed` —
    accepts a capacity-padded EdgeList unchanged.  This is the shape
    contract of the streaming graph store's capacity classes: all graphs
    in a class share one compiled program.
    """
    e = g.num_edges
    if capacity < e:
        raise ValueError(f"capacity {capacity} < num_edges {e}")
    if capacity == e:
        return g
    pad = capacity - e
    return EdgeList(
        src=jnp.concatenate([g.src, jnp.zeros((pad,), jnp.int32)]),
        dst=jnp.concatenate([g.dst, jnp.zeros((pad,), jnp.int32)]),
        weight=jnp.concatenate([g.weight, jnp.zeros((pad,), jnp.float32)]),
        num_nodes=g.num_nodes,
    )


def incidence_matrix(g: EdgeList) -> jax.Array:
    """Dense incidence matrix X (E x N): +1 at min index, -1 at max index."""
    e = g.num_edges
    x = jnp.zeros((e, g.num_nodes), dtype=jnp.float32)
    rows = jnp.arange(e)
    x = x.at[rows, g.src].set(1.0)
    x = x.at[rows, g.dst].set(-1.0)
    return x


def adjacency_dense(g: EdgeList) -> jax.Array:
    a = jnp.zeros((g.num_nodes, g.num_nodes), dtype=jnp.float32)
    a = a.at[g.src, g.dst].add(g.weight)
    a = a.at[g.dst, g.src].add(g.weight)
    return a


def degrees(g: EdgeList) -> jax.Array:
    d = jnp.zeros((g.num_nodes,), dtype=jnp.float32)
    d = d.at[g.src].add(g.weight)
    d = d.at[g.dst].add(g.weight)
    return d


def laplacian_dense(g: EdgeList) -> jax.Array:
    """L = D - A, symmetric PSD.  Equals X^T diag(w) X (tested)."""
    a = adjacency_dense(g)
    return jnp.diag(jnp.sum(a, axis=1)) - a


def normalized_laplacian_dense(g: EdgeList, eps: float = 1e-12) -> jax.Array:
    a = adjacency_dense(g)
    d = jnp.sum(a, axis=1)
    inv_sqrt = jnp.where(d > 0, jax.lax.rsqrt(jnp.maximum(d, eps)), 0.0)
    return jnp.eye(g.num_nodes) - (inv_sqrt[:, None] * a) * inv_sqrt[None, :]


# ---------------------------------------------------------------------------
# Matrix-free Laplacian matvec from edge lists.
# ---------------------------------------------------------------------------

def edge_matvec_arrays(src: jax.Array, dst: jax.Array, weight: jax.Array,
                       v: jax.Array) -> jax.Array:
    """Raw-array Laplacian matvec: Σ_e w_e x_e (x_eᵀ v) from bare edge
    buffers.  The single implementation of the edge-wise gather/scatter;
    every consumer (EdgeList matvec, graph-store ticks, eigen-update
    deltas, sharded shards) wraps this.  Zero-weight slots are inert, so
    capacity-padded buffers pass through unchanged.
    """
    diff = v[src] - v[dst]  # (E,) or (E, K) == X @ v
    if diff.ndim == 1:
        wdiff = weight * diff
    else:
        wdiff = weight[:, None] * diff
    out = jnp.zeros_like(v)
    out = out.at[src].add(wdiff)
    out = out.at[dst].add(-wdiff)
    return out


def laplacian_matvec(g: EdgeList, v: jax.Array) -> jax.Array:
    """L @ v computed edge-wise: sum_e w_e * x_e (x_e^T v).

    v: (N,) or (N, K).  Cost O(E*K); never materializes L.
    """
    return edge_matvec_arrays(g.src, g.dst, g.weight, v)


def minibatch_laplacian_matvec(
    src: jax.Array, dst: jax.Array, weight: jax.Array, v: jax.Array,
    num_edges_total: int,
) -> jax.Array:
    """Unbiased estimate of L @ v from a minibatch of B edges.

    E[ (E_total / B) * sum_{e in batch} w_e x_e x_e^T v ] = L v  when edges
    are drawn uniformly with replacement.  This is the stochastic
    optimization model of the paper (Sec. 3): batches of edge vectors x_e.
    """
    b = src.shape[0]
    diff = v[src] - v[dst]  # (B,) or (B, K), matching v's rank
    scaled = weight * (num_edges_total / b)
    wdiff = scaled * diff if diff.ndim == 1 else scaled[:, None] * diff
    out = jnp.zeros_like(v)
    out = out.at[src].add(wdiff)
    out = out.at[dst].add(-wdiff)
    return out


def spectral_radius_upper_bound(g: EdgeList) -> jax.Array:
    """lambda_max(L) <= 2 * max weighted degree (paper Sec. 5.4)."""
    return 2.0 * jnp.max(degrees(g))


# ---------------------------------------------------------------------------
# Edge incidence graph (Sec. 4.3, Table 1).
# ---------------------------------------------------------------------------

def edge_inner_product(si, di, sj, dj) -> jax.Array:
    """x_ei^T x_ej per Table 1 of the paper.

    repeated -> 2; serial (share one node at 'opposite signs') -> -1;
    converging/diverging (share one node at 'same sign') -> +1;
    disconnected -> 0.  Signs follow the min/max encoding: +1 at src=min,
    -1 at dst=max.
    """
    si, di, sj, dj = (jnp.asarray(a) for a in (si, di, sj, dj))
    ip = (
        (si == sj).astype(jnp.float32)  # +1 * +1
        + (di == dj).astype(jnp.float32)  # -1 * -1
        - (si == dj).astype(jnp.float32)  # +1 * -1
        - (di == sj).astype(jnp.float32)  # -1 * +1
    )
    return ip


class EdgeIncidence(NamedTuple):
    """Padded adjacency of the edge incidence graph.

    Node u of this graph = edge u of the original graph.  Two edges are
    adjacent iff they share an endpoint; every edge also has a self loop
    (paper footnote 1).  `nbrs[e, :deg[e]]` lists neighbours, padded with
    `e` itself (padding never sampled because indices are drawn < deg).
    """

    nbrs: jax.Array  # (E, max_deg) int32
    deg: jax.Array  # (E,) int32 — degree in the incidence graph (incl. self loop)
    ip: jax.Array  # (E, max_deg) float32 — x_e^T x_nbr per slot
    deg_star_inc: int  # static upper bound 2*deg*-1 on incidence degree


def build_edge_incidence(g: EdgeList) -> EdgeIncidence:
    """Host-side (numpy) construction of the padded incidence-graph adjacency."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    e = src.shape[0]
    n = g.num_nodes
    node2edges: list[list[int]] = [[] for _ in range(n)]
    for idx in range(e):
        node2edges[src[idx]].append(idx)
        node2edges[dst[idx]].append(idx)
    nbr_lists = []
    for idx in range(e):
        s = set(node2edges[src[idx]]) | set(node2edges[dst[idx]])
        s.add(idx)  # self loop
        nbr_lists.append(sorted(s))
    max_deg = max(len(l) for l in nbr_lists)
    nbrs = np.full((e, max_deg), 0, dtype=np.int32)
    deg = np.zeros((e,), dtype=np.int32)
    for idx, l in enumerate(nbr_lists):
        nbrs[idx, : len(l)] = l
        deg[idx] = len(l)
        nbrs[idx, len(l):] = idx  # pad with self (never sampled)
    nbrs_j = jnp.asarray(nbrs)
    deg_j = jnp.asarray(deg)
    ip = edge_inner_product(
        g.src[:, None], g.dst[:, None], g.src[nbrs_j], g.dst[nbrs_j]
    )
    node_deg = np.zeros((n,), np.int64)
    np.add.at(node_deg, src, 1)
    np.add.at(node_deg, dst, 1)
    deg_star = int(node_deg.max()) if e else 1
    return EdgeIncidence(
        nbrs=nbrs_j, deg=deg_j, ip=ip, deg_star_inc=2 * deg_star - 1
    )
