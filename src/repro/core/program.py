"""Unified solve programs: ONE dilated solve loop for every deployment shape.

Before this module the repo carried four hand-rolled copies of the same
iteration — ``core.solvers.run_solver``'s eval loop, the streaming
service's segment and pallas tick builders, and ``stream.sharded``'s
shard_mapped tick programs — so every convergence improvement (adaptive
lr, probe-driven degrees, smarter stopping) had to be implemented four
times or not at all.  The builders here own the composition

    dilated matvec  x  mu-EG/Oja step  x  residual evaluation

as one compiled unit, parameterized along three axes:

* **operator source** — raw edge arrays (segment gather/scatter), a
  node-blocked pallas layout with the dilation AXPY fused into the
  kernel epilogue, or per-shard sharded layouts whose matvecs psum
  under ``shard_map``;
* **batching shape** — a single panel (`run_chunk`, `run_program`), a
  vmapped/``lax.map``-ped session group (`build_tick_program` without a
  mesh), or a shard_mapped capacity class (`build_tick_program` with a
  mesh);
* **a** :class:`StepSchedule` — the compile-relevant statics (solver
  method, dilation degree, steps per invocation), derived from a
  session's :class:`~repro.spectral.plan.DilationPlan` instead of fixed
  constants, while the per-session learning rate and dilation scale
  ride as TRACED inputs so adaptive per-session hyperparameters never
  grow the compile cache.

:func:`apply_solver_step` is THE single construction site of the
mu-EG/Oja dilated solver step; ``core.solvers.run_solver``,
``stream.service``'s tick programs, ``stream.warm``'s chunk runner, and
``core.distributed``'s whole-series solves are thin wrappers over the
loops below.

The scheduling helpers at the bottom (`contraction_rate`,
`predicted_residual`, `predicted_steps_to_tol`) turn observed residual
decay into step forecasts — the streaming service's residual-decay tick
scheduler and its predicted-contraction stopping are built on them.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import backend as backend_mod
from repro.core import metrics, operators, solvers
from repro.core import laplacian as lap
from repro.kernels.edge_spmm import ops as es_ops

MatVec = Callable[[jax.Array], jax.Array]


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepSchedule:
    """Compile-relevant hyperparameters of one solve-program invocation.

    ``method`` / ``degree`` / ``steps`` / ``backend`` are STATIC — they
    are part of the compile-cache key, and adaptive layers must only
    move them on snapped grids (see :func:`schedule_degrees`).  ``lr``
    is advisory metadata for SINGLE-PANEL callers (a plan-derived step
    size to feed ``run_chunk``/``SolverConfig``); the group tick
    builders never read it — their learning rates always arrive as the
    traced per-session ``lrs`` input, so per-session values are free
    (no recompilation).
    """

    method: str = "mu_eg"  # "mu_eg" | "oja"
    degree: int = 15  # dilation degree of the (I - c L)^degree operator
    steps: int = 20  # solver steps per program invocation
    lr: float = 0.3  # advisory: single-panel callers; ticks trace lrs
    backend: str = "auto"  # repro.core.backend

    @property
    def statics(self) -> tuple:
        """The compile-cache key contribution of this schedule."""
        return (self.method, self.degree, self.steps, self.backend)

    @classmethod
    def from_plan(cls, plan, *, steps: int, base_lr: float,
                  method: str = "mu_eg", backend: str = "auto",
                  max_degree: int | None = None,
                  normalized: bool = True) -> "StepSchedule":
        """Derive (lr, degree) from a :class:`DilationPlan`.

        ``normalized=True`` is the tick-program form ``(I - c L)^degree``
        whose TOP eigenvalue is 1 by construction (an identity plan runs
        as degree 1 with ``c = 1/lambda_star`` — the scaled operator and
        the rescaled ``suggested_lr`` cancel exactly for the linear
        mu-EG/Oja updates), so the lr is instead normalized to the
        plan's WANTED-direction scale (:func:`session_lr`) — the axis
        along which plans genuinely differ.  ``normalized=False`` keeps
        ``plan.suggested_lr`` verbatim for callers driving the raw
        reversed operator ``lambda* I - S(L)`` (one-shot solves over
        ``planned_operator``).
        """
        degree = 1 if plan.family == "identity" else int(plan.degree)
        if max_degree is not None:
            cap = max_degree if max_degree % 2 == 1 else max_degree - 1
            degree = min(degree, max(cap, 1))
        if normalized:
            lr = session_lr(plan, base_lr)
        else:
            lr = plan.suggested_lr(base_lr)
        return cls(method=method, degree=degree, steps=steps, lr=lr,
                   backend=backend)


def wanted_scale(plan) -> float:
    """Transformed operator value of the slowest WANTED direction.

    Dilation deliberately decays the wanted spread — the planner allows
    ``tau * lam_k / rho`` up to ``MAX_WANTED_DECAY``, i.e. wanted
    directions down to ``exp(-1.5) ~ 0.22`` — and the mu-EG/Oja utility
    gradient of that trailing direction scales with this value, so a
    step size tuned for a unit-scale direction under-steps it by
    exactly this factor.  This is the denominator of the per-session lr
    normalization (:func:`session_lr`).
    """
    if plan.family == "identity":
        lam_star = max(plan.lambda_star, 1e-30)
        return max(1.0 - plan.lam_k / lam_star, 1e-3)
    if plan.rho <= 0.0 or not math.isfinite(plan.rho):
        return 1.0
    return math.exp(-plan.tau * min(plan.lam_k, plan.rho) / plan.rho)


# The top direction still sees operator value 1, so the wanted-scale lr
# boost must stay inside the solver's stable step range.
LR_BOOST_CAP = 2.0


def session_lr(plan, base_lr: float, boost_cap: float = LR_BOOST_CAP
               ) -> float:
    """Plan-driven per-session step size for the unit-normalized tick
    program form: the base lr boosted by the inverse wanted-direction
    scale (capped).  Strongly dilated tenants — whose trailing wanted
    eigenvalue the transform decayed hardest — take proportionally
    larger steps; tenants with their wanted spread intact keep the
    base lr."""
    return base_lr * min(1.0 / max(wanted_scale(plan), 1e-3), boost_cap)


def dilation_scale(plan, degree: int) -> float:
    """Per-matvec scale ``c`` of the ``(I - c L)^degree`` program form.

    For the exp-family plans this is the series step ``tau / (rho *
    degree)``; an identity plan maps onto degree 1 with ``c = 1 /
    lambda_star`` (the unit-normalized reversed identity — see
    :meth:`StepSchedule.from_plan` for why the lr needs no compensation).
    """
    if plan.family == "identity":
        return 1.0 / max(plan.lambda_star, 1e-30)
    return plan.scale / max(degree, 1)


def schedule_degrees(max_degree: int) -> tuple[int, ...]:
    """Every degree a plan-derived schedule may take under ``max_degree``.

    The planner emits degrees only from the snapped tau grid (plus the
    identity's degree 1 and the budget-truncation fallback), so
    per-class degree re-planning moves on THIS set — the compile-cache
    economy bound asserted by the schedule-plumbing tests.
    """
    from repro.spectral import plan as plan_mod

    degs = {1, plan_mod.MIN_DEGREE}
    for t in plan_mod.TAU_GRID:
        d = int(math.ceil(plan_mod.DEGREE_PER_TAU * t))
        d = d if d % 2 == 1 else d + 1
        degs.add(max(d, plan_mod.MIN_DEGREE))
    degs.add(max(max_degree if max_degree % 2 == 1 else max_degree - 1, 1))
    return tuple(sorted(d for d in degs if d <= max_degree))


# ---------------------------------------------------------------------------
# collective accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PsumStats:
    """Trace-time psum-call counts of a shard_mapped tick program.

    ``fused`` counts TUPLE psums (several operands in one call — XLA
    lowers them to ONE variadic all-reduce), ``plain`` single-operand
    calls.  Loop bodies (scan/fori) trace once, so the counts are per
    TRACED body, independent of step counts: the model-sharded tick's
    contract — exactly one fused collective per solver step — shows up
    as ``fused == 1``.
    """

    plain: int = 0
    fused: int = 0


_PSUM_STATS: PsumStats | None = None


@contextlib.contextmanager
def count_psums():
    """Count collective calls issued while TRACING under this context
    (e.g. ``jax.eval_shape`` of a tick program) — the weak-scaling
    benchmarks' and tests' fused-collective assertion hook."""
    global _PSUM_STATS
    prev, _PSUM_STATS = _PSUM_STATS, PsumStats()
    try:
        yield _PSUM_STATS
    finally:
        _PSUM_STATS = prev


def _psum(x, axes):
    """jax.lax.psum routed through the trace-time counter.  Every
    collective the tick builders below issue goes through here."""
    if _PSUM_STATS is not None:
        if isinstance(x, tuple):
            _PSUM_STATS.fused += 1
        else:
            _PSUM_STATS.plain += 1
    return jax.lax.psum(x, axes)


# ---------------------------------------------------------------------------
# the solver step — THE single construction site
# ---------------------------------------------------------------------------

def apply_solver_step(step_fn, state: solvers.SolverState, av: jax.Array,
                      lr, gram: jax.Array | None = None
                      ) -> solvers.SolverState:
    """THE construction site of the mu-EG/Oja dilated solver step.

    Every solve loop in the repo — one-shot, streaming segment/pallas
    ticks, sharded class ticks, model-sharded panel ticks, distributed
    series solves, warm reconvergence chunks — applies its solver update
    through this call; nothing else composes an operator application
    with a solver step.

    ``gram`` is the fused-collective hook: when the caller already holds
    the global 2k x 2k gram of [V | AV] (a model-sharded tick psums
    per-shard grams fused with its panel assembly), the mu-EG update
    runs as the row-local mix :func:`solvers.mu_eg_step_from_gram` on
    whatever row slice ``state``/``av`` hold — no second panel
    reduction.  ``gram=None`` is every other path: the step function
    computes its own panel products.
    """
    if gram is not None:
        return solvers.mu_eg_step_from_gram(state, av, gram, lr)
    return step_fn(state, av, lr)


# ---------------------------------------------------------------------------
# single-panel loops
# ---------------------------------------------------------------------------

def run_chunk(opv: MatVec, step_fn, state: solvers.SolverState, lr,
              steps: int) -> tuple[solvers.SolverState, jax.Array]:
    """``steps`` dilated solver steps on one panel + one residual eval.

    The building block of ``stream.warm``'s chunked reconvergence and of
    the per-session tick bodies (which batch it via vmap/``lax.map``).
    """
    def body(st, _):
        return apply_solver_step(step_fn, st, opv(st.v), lr), None

    state, _ = jax.lax.scan(body, state, None, length=steps)
    return state, metrics.operator_residual(opv, state.v)


def run_program(
    operator,
    n: int,
    cfg: solvers.SolverConfig,
    v_star: jax.Array | None = None,
    stochastic: bool = False,
    init_v: jax.Array | None = None,
) -> tuple[solvers.SolverState, "solvers.Trace"]:
    """One-shot solve with ground-truth traces — ``run_solver``'s engine.

    One jitted scan over eval chunks (Python overhead O(1) in steps);
    ``init_v`` warm-starts from an (n, k) panel via ``init_from_panel``.
    Stochastic operators take a per-step PRNG key.
    """
    step_fn = solvers.make_step_fn(cfg.method, cfg.backend)
    key = jax.random.PRNGKey(cfg.seed)
    key, init_key = jax.random.split(key)
    if init_v is None:
        state0 = solvers.init_state(init_key, n, cfg.k)
    else:
        state0 = solvers.init_from_panel(init_v)
    num_evals = max(1, cfg.steps // cfg.eval_every)
    if v_star is None:
        v_star = jnp.zeros((n, cfg.k))

    def one_step(state, key_step):
        if stochastic:
            av = operator(key_step, state.v)
        else:
            av = operator(state.v)
        return apply_solver_step(step_fn, state, av, cfg.lr), None

    def eval_chunk(state, chunk_keys):
        state, _ = jax.lax.scan(one_step, state, chunk_keys)
        m = (
            state.step,
            metrics.subspace_error(state.v, v_star),
            metrics.eigenvector_streak(state.v, v_star),
        )
        return state, m

    keys = jax.random.split(key, num_evals * cfg.eval_every).reshape(
        num_evals, cfg.eval_every, -1)

    run = jax.jit(lambda s, ks: jax.lax.scan(eval_chunk, s, ks))
    final, (steps, err, streak) = run(state0, keys)
    return final, solvers.Trace(steps=steps, subspace_error=err,
                                streak=streak)


# ---------------------------------------------------------------------------
# batched (session-group) loop
# ---------------------------------------------------------------------------

def _group_loop(opv_all, step_all, vs, lrs, steps: int, chunks):
    """The batched dilated solve loop every tick program runs.

    ``opv_all``: (G, n, k) -> (G, n, k) dilated-operator application for
    the whole stacked group (psums live inside it on sharded sources);
    ``step_all`` maps the solver step over the group axis (vmap on
    segment, ``lax.map`` on pallas — its grids don't vmap).

    ``chunks`` is the residual-decay scheduler's tick MULTIPLIER: a
    traced scalar runs ``chunks * steps`` solver steps for every member
    before the single residual evaluation, and a traced PER-SESSION
    ``(G,)`` vector gives each member its own chunk budget — session i
    steps for ``chunks[i] * steps`` steps and then FREEZES (its panel
    stops moving under a mask) while slower group peers keep iterating
    up to ``max(chunks)``, so one member forecast to converge soon no
    longer caps the whole group's cadence at multiplier 1.  Either way
    the value is TRACED (the static scan of ``steps`` steps repeats
    under a ``fori_loop`` with a traced bound, the freeze is a
    ``where``), so scheduled multi-chunk ticks reuse the exact compiled
    program of a plain tick — the adaptive layer costs zero
    recompilation.
    """
    state = solvers.SolverState(
        v=vs, step=jnp.zeros((vs.shape[0],), jnp.int32))
    chunks = jnp.asarray(chunks, jnp.int32)
    per_session = jnp.broadcast_to(chunks, (vs.shape[0],))

    def body(st, _):
        return step_all(st, opv_all(st.v), lrs), None

    def chunk_body(i, st):
        stepped, _ = jax.lax.scan(body, st, None, length=steps)
        live = i < per_session  # (G,) — members past their budget freeze
        return solvers.SolverState(
            v=jnp.where(live[:, None, None], stepped.v, st.v),
            step=jnp.where(live, stepped.step, st.step))

    state = jax.lax.fori_loop(0, jnp.max(per_session), chunk_body, state)
    avs = opv_all(state.v)
    return state.v, jax.vmap(metrics.panel_residual)(state.v, avs)


def _vmapped_step(step_fn):
    def step_all(st, avs, lrs):
        return jax.vmap(
            lambda s, av, lr: apply_solver_step(step_fn, s, av, lr)
        )(st, avs, lrs)
    return step_all


def _mapped_step(step_fn):
    """``lax.map`` variant for pallas steps (kernel grids don't vmap)."""
    def step_all(st, avs, lrs):
        return jax.lax.map(
            lambda args: apply_solver_step(
                step_fn,
                solvers.SolverState(v=args[0], step=args[1]),
                args[2], args[3]),
            (st.v, st.step, avs, lrs))
    return step_all


def _blocked_opv_all(u_local, other, w, cb, deg, cs, degree: int,
                     block_n: int, num_chunks: int, block_e: int,
                     interpret: bool, edge_axes=None):
    """Group dilated operator over stacked node-blocked pallas layouts.

    With ``edge_axes`` the layouts are per-shard (leading shard axis
    inside each device's slice) and every matvec psums; the dilation
    AXPY then applies post-psum (the collective is the fusion barrier).
    Without it the single-device kernel fuses ``alpha=-c, beta=1`` into
    its epilogue.  ``cb`` is the per-session (or per-shard) stacked
    chunk->block index map of the CSR chunk layout.
    """
    def local_mv(args):
        # shard_map-local slices: the leading shard axis is partitioned
        # down to size 1 inside the body (es_ops.shard_local_blocking)
        ul, ot, wt, cbv, dg, x = args
        nb = es_ops.shard_local_blocking(
            ul, ot, wt, cbv, dg, block_n=block_n, block_e=block_e,
            num_chunks=num_chunks, num_nodes=x.shape[0])
        return es_ops.edge_spmm_blocked(nb, x, interpret=interpret)

    def fused_mv(args):
        ul, ot, wt, cbv, dg, x, c = args
        nb = es_ops.NodeBlocking(
            u_local=ul, other=ot, weight=wt, chunk_block=cbv, deg=dg,
            block_n=block_n, block_e=block_e, num_chunks=num_chunks,
            num_nodes=x.shape[0])
        return es_ops.edge_spmm_blocked(nb, x, alpha=-c, beta=1.0,
                                        interpret=interpret)

    def opv_all(us):
        def body(_, xs):
            if edge_axes is not None:
                lxs = _psum(
                    jax.lax.map(local_mv,
                                (u_local, other, w, cb, deg, xs)),
                    edge_axes)
                return xs - cs[:, None, None] * lxs
            return jax.lax.map(fused_mv,
                               (u_local, other, w, cb, deg, xs, cs))
        return jax.lax.fori_loop(0, degree, body, us)

    return opv_all


def build_tick_segment(schedule: StepSchedule):
    """Single-device segment tick: fn(src, dst, w, vs, cs, lrs, chunks).

    Inputs are the group's stacked (G, cap) edge buffers, (G, n, k)
    panels, traced per-session (G,) dilation scales / learning rates,
    and the traced chunk multiplier; one compiled program per
    (schedule statics, shapes).
    """
    step_fn = solvers.STEP_FNS[schedule.method]
    degree, steps = schedule.degree, schedule.steps

    def tick(src, dst, w, vs, cs, lrs, chunks):
        def opv_all(us):
            return jax.vmap(
                lambda s, d, wt, x, c:
                operators.dilated_operator_arrays(s, d, wt, c, degree)(x)
            )(src, dst, w, us, cs)

        return _group_loop(opv_all, _vmapped_step(step_fn), vs, lrs,
                           steps, chunks)

    return jax.jit(tick)


def build_tick_pallas(schedule: StepSchedule, block_n: int,
                      num_chunks: int, block_e: int):
    """Single-device pallas tick:
    fn(u_local, other, w, cb, deg, vs, cs, lrs, chunks).

    The dilated matvec runs the node-blocked incidence-SpMM kernel with
    the dilation AXPY (alpha=-c, beta=1) fused into its epilogue, and
    the solver step uses the fused mu-EG kernel; sessions advance under
    ``lax.map`` (pallas grids don't vmap across the session axis).
    ``cb`` is the stacked (G, NC+1) chunk->block map steering the
    kernel's scalar-prefetched BlockSpecs.
    """
    interp = backend_mod.kernel_interpret()
    step_fn = solvers.make_step_fn(schedule.method, "pallas")
    degree, steps = schedule.degree, schedule.steps

    def tick(u_local, other, w, cb, deg, vs, cs, lrs, chunks):
        opv_all = _blocked_opv_all(u_local, other, w, cb, deg, cs, degree,
                                   block_n, num_chunks, block_e,
                                   interp)
        return _group_loop(opv_all, _mapped_step(step_fn), vs, lrs,
                           steps, chunks)

    return jax.jit(tick)


def build_tick_sharded_segment(schedule: StepSchedule, mesh, edge_axes):
    """Sharded segment tick: the group's stacked (G, cap) edge buffers
    shard over ``edge_axes`` along the capacity axis; each dilation step
    is the per-shard vmapped gather/scatter + ONE psum of the stacked
    (G, n, k) panels (same decomposition as PR 4's tick programs)."""
    step_fn = solvers.STEP_FNS[schedule.method]
    degree, steps = schedule.degree, schedule.steps
    spec_e = P(None, edge_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False)  # scan carries mix varying/unvarying values
    def tick(src, dst, w, vs, cs, lrs, chunks):
        local_mv = jax.vmap(lap.edge_matvec_arrays)

        def opv_all(us):
            def body(_, xs):
                lxs = _psum(local_mv(src, dst, w, xs), edge_axes)
                return xs - cs[:, None, None] * lxs
            return jax.lax.fori_loop(0, degree, body, us)

        return _group_loop(opv_all, _vmapped_step(step_fn), vs, lrs,
                           steps, chunks)

    return jax.jit(tick)


def build_tick_sharded_pallas(schedule: StepSchedule, mesh, edge_axes,
                              block_n: int, num_chunks: int,
                              block_e: int):
    """Sharded pallas tick: per-shard node-blocked kernels + one psum.

    fn(u_local, other, w, cb, deg, vs, cs, lrs, chunks) with (G, S, ...)
    stacked per-shard layouts sharded over ``edge_axes`` along the
    shard axis; the AXPY applies post-psum (beta must apply exactly
    once, so the kernel-epilogue fusion is single-device-only) and the
    solver step maps the fused mu-EG kernel under ``lax.map``.
    """
    interp = backend_mod.kernel_interpret()
    step_fn = solvers.make_step_fn(schedule.method, "pallas")
    degree, steps = schedule.degree, schedule.steps
    spec_b = P(None, edge_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_b, spec_b, spec_b, spec_b, spec_b,
                  P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False)  # pallas_call has no replication rule
    def tick(u_local, other, w, cb, deg, vs, cs, lrs, chunks):
        opv_all = _blocked_opv_all(u_local, other, w, cb, deg, cs, degree,
                                   block_n, num_chunks, block_e,
                                   interp, edge_axes=edge_axes)
        return _group_loop(opv_all, _mapped_step(step_fn), vs, lrs,
                           steps, chunks)

    return jax.jit(tick)


def num_model_shards(mesh, model_axes=("model",)) -> int:
    """Product of the mesh's panel-sharding axis sizes."""
    s = 1
    for a in model_axes:
        s *= mesh.shape[a]
    return s


def build_tick_model_sharded(schedule: StepSchedule, mesh, model_axes,
                             block_n: int, num_chunks: int, block_e: int):
    """PANEL-sharded tick: fn(u_local, other, w, cb, deg, vs, cs, lrs,
    chunks) over destination-aligned per-shard layouts
    (:class:`~repro.kernels.edge_spmm.ops.ModelShardedBlocking`, stacked
    (G, S, ...) and sharded over ``model_axes`` along the shard axis).

    Each shard owns a contiguous row range of the (n, k) panel outright:
    its local matvec rows are FINAL (the dilation AXPY fuses back into
    the per-shard kernel epilogue — unlike the edge-sharded ticks, where
    beta must wait for the psum), and the collectives per dilated apply
    merely ASSEMBLE disjoint row ranges.  The mu-EG step then needs only
    the global 2k x 2k gram of [V | AV] (``solvers.panel_gram2k``), which
    is a sum of per-shard grams over owned rows — so the LAST matvec of
    the dilation ships its row assembly and the grams in ONE fused
    collective::

        av_full, grams = psum((embed(av_rows), gram_s), model_axes)

    and every shard mixes its rows row-locally
    (:func:`apply_solver_step` with ``gram=``) with zero further
    communication.  Per solver step: ``degree`` psums total, EXACTLY ONE
    of them fused — the gram costs no extra collective over the matvecs
    the dilation already pays (the gather-then-gram alternative pays
    ``degree + 1``).  ``count_psums`` asserts this at trace time.

    ``schedule.backend`` picks the per-shard row computation: the
    scalar-prefetched chunk kernel ("pallas") or the segment
    gather/scatter over the same layout arrays ("segment"/"auto"
    off-TPU).  Oja has no gram form (its QR retraction needs the full
    panel), so it assembles plainly and steps replicated — mu-EG is the
    fused-collective path.
    """
    interp = backend_mod.kernel_interpret()
    use_kernel = backend_mod.resolve_backend(schedule.backend) == "pallas"
    step_fn = solvers.make_step_fn(schedule.method, schedule.backend)
    fused_gram = schedule.method == "mu_eg"
    degree, steps = schedule.degree, schedule.steps
    num_shards = num_model_shards(mesh, model_axes)
    spec_b = P(None, model_axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_b, spec_b, spec_b, spec_b, spec_b,
                  P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False)  # pallas_call has no replication rule
    def tick(u_local, other, w, cb, deg, vs, cs, lrs, chunks):
        g, n, k = vs.shape
        rows = deg.shape[-1]
        n_pad = num_shards * rows
        sidx = jnp.zeros((), jnp.int32)
        for a in model_axes:
            sidx = sidx * mesh.shape[a] + jax.lax.axis_index(a)
        row_start = sidx * rows
        vp = jnp.pad(vs.astype(jnp.float32),
                     ((0, 0), (0, n_pad - n), (0, 0)))

        def mv_one(args):
            ul, ot, wt, cbv, dg, xf, c = args
            ab = jnp.stack([-c, jnp.ones_like(c)]).astype(jnp.float32)
            return es_ops.model_local_rows(
                ul[0], ot[0], wt[0], cbv[0], dg[0], xf, ab, row_start,
                block_n=block_n, block_e=block_e, num_chunks=num_chunks,
                padded_nodes=n_pad, use_kernel=use_kernel,
                interpret=interp)

        def mv_all(full):
            # (G, n_pad, k) replicated -> (G, rows, k) FINAL owned rows
            # of (I - c L) applied per session
            return jax.lax.map(
                mv_one, (u_local, other, w, cb, deg, full, cs))

        def embed(ys):
            z = jnp.zeros((g, n_pad, k), jnp.float32)
            return jax.lax.dynamic_update_slice(z, ys, (0, row_start, 0))

        def dilated_local(full):
            # degree - 1 matvecs with plain row assembly; the LAST
            # matvec's rows stay local so its assembly can fuse with
            # whatever reduction the caller needs next
            def body(_, fz):
                return _psum(embed(mv_all(fz)), model_axes)
            fz = jax.lax.fori_loop(0, degree - 1, body, full)
            return mv_all(fz)

        def step_one(vv, st, av, lr, gr=None):
            return apply_solver_step(
                step_fn, solvers.SolverState(v=vv, step=st), av, lr,
                gram=gr)

        def step_body(carry, _):
            vloc, full, stepc = carry
            av_loc = dilated_local(full)
            if fused_gram:
                grams = jax.vmap(solvers.panel_gram2k)(vloc, av_loc)
                # THE fused collective: row assembly + gram reduction
                av_full, grams = _psum((embed(av_loc), grams), model_axes)
                stepped = jax.vmap(step_one)(vloc, stepc, av_loc, lrs,
                                             grams)
                new_full = jax.vmap(step_one)(full, stepc, av_full, lrs,
                                              grams).v
                return (stepped.v, new_full, stepped.step), None
            # no gram form (oja): assemble plainly, step replicated
            av_full = _psum(embed(av_loc), model_axes)
            stepped = jax.vmap(step_one)(full, stepc, av_full, lrs)
            new_loc = jax.lax.dynamic_slice(
                stepped.v, (0, row_start, 0), (g, rows, k))
            return (new_loc, stepped.v, stepped.step), None

        per_session = jnp.broadcast_to(jnp.asarray(chunks, jnp.int32),
                                       (g,))
        vloc0 = jax.lax.dynamic_slice(vp, (0, row_start, 0),
                                      (g, rows, k))
        carry0 = (vloc0, vp, jnp.zeros((g,), jnp.int32))

        def chunk_body(i, carry):
            stepped, _ = jax.lax.scan(step_body, carry, None,
                                      length=steps)
            live = i < per_session  # (G,) freeze mask past the budget
            return tuple(
                jnp.where(live.reshape((g,) + (1,) * (s.ndim - 1)), s, c)
                for s, c in zip(stepped, carry))

        _, full, _ = jax.lax.fori_loop(0, jnp.max(per_session),
                                       chunk_body, carry0)
        av_full = _psum(embed(dilated_local(full)), model_axes)
        res = jax.vmap(metrics.panel_residual)(full, av_full)
        return full[:, :n, :], res

    return jax.jit(tick)


def build_tick_program(schedule: StepSchedule, *, layout=None, mesh=None,
                       edge_axes=("data",), model_axes=None):
    """One compiled batched tick program for a session group.

    ``layout`` is None for the segment operator source or the pallas
    blocking statics ``(block_n, num_chunks, block_e)``; ``mesh``
    switches to the shard_mapped variants; ``model_axes`` (with a mesh
    and a layout) selects the PANEL-sharded tick over destination-
    aligned layouts (:func:`build_tick_model_sharded` — one fused
    rows+gram collective per solver step).  The streaming service keys
    the returned program by its (capacity class, degree, layout,
    occupancy bucket, schedule statics); the per-session lr/scale AND
    the scheduler's tick multipliers (scalar or per-session ``(G,)``
    chunk budgets — see :func:`_group_loop`) are traced inputs — the
    whole adaptive layer moves underneath one compiled program.
    """
    if mesh is not None and model_axes is not None:
        if layout is None:
            raise ValueError(
                "the model-sharded tick needs the blocking layout "
                "statics (block_n, num_chunks, block_e)")
        return build_tick_model_sharded(schedule, mesh, model_axes,
                                        *layout)
    if mesh is not None and layout is not None:
        return build_tick_sharded_pallas(schedule, mesh, edge_axes, *layout)
    if mesh is not None:
        return build_tick_sharded_segment(schedule, mesh, edge_axes)
    if layout is not None:
        return build_tick_pallas(schedule, *layout)
    return build_tick_segment(schedule)


# ---------------------------------------------------------------------------
# residual-decay forecasting (the adaptive scheduler's math)
# ---------------------------------------------------------------------------

def contraction_rate(res_prev: float, res: float,
                     steps: int) -> float | None:
    """Measured per-step residual decay ratio, or None when the pair of
    observations carries no usable contraction signal (non-finite,
    non-positive, zero steps, or not actually decaying)."""
    if steps <= 0 or not (math.isfinite(res_prev) and math.isfinite(res)):
        return None
    if not (0.0 < res < res_prev):
        return None
    return (res / res_prev) ** (1.0 / steps)


def predicted_residual(res: float, rate: float, steps: int) -> float:
    """Forecast the panel residual after ``steps`` more solver steps."""
    return res * rate ** steps


def predicted_steps_to_tol(res: float, rate: float | None,
                           tol: float) -> int:
    """Predicted-contraction stopping: solver steps until the residual
    is forecast to reach ``tol`` (0 when already there; a large sentinel
    when the rate predicts no convergence)."""
    if res <= tol:
        return 0
    if rate is None or not (0.0 < rate < 1.0):
        return 1 << 30
    return int(math.ceil(math.log(tol / res) / math.log(rate)))


__all__ = [
    "PsumStats",
    "StepSchedule",
    "apply_solver_step",
    "build_tick_model_sharded",
    "build_tick_pallas",
    "build_tick_program",
    "build_tick_segment",
    "build_tick_sharded_pallas",
    "build_tick_sharded_segment",
    "contraction_rate",
    "count_psums",
    "num_model_shards",
    "dilation_scale",
    "predicted_residual",
    "predicted_steps_to_tol",
    "run_chunk",
    "run_program",
    "schedule_degrees",
    "session_lr",
    "wanted_scale",
]
