"""Series approximations to spectrum transforms (paper Sec. 4.2, Table 2).

Each series S provides ``apply(matvec, v)`` computing S(L) @ v with
``degree`` Laplacian matvecs of an (n, k) panel — never an n x n product —
plus ``scalar(lam)`` (the induced spectral map, for analysis/tests) and a
reversal shift ``lambda_star`` folding in Eq. (8).

Numerical note: a degree-251 polynomial CANNOT be evaluated in the power
basis (binomial coefficients ~1e74 with alternating signs).  Every series
here is evaluated with its numerically stable recurrence:

  * ``taylor_log``:     log(L+eps I) ~ sum (-1)^{i+1} M^i / i,
                        M = L-(1-eps)I; recurrence  m <- M m   (Table 2)
  * ``taylor_neg_exp``: -e^{-L} ~ -sum (-L)^i / i!;
                        recurrence  t <- -(L t)/i              (Table 2)
  * ``limit_neg_exp``:  -(I - L/l)^l, l odd;
                        recurrence  u <- u - (L u)/l, l times  (Table 2)
  * ``cheb``:           beyond-paper Chebyshev fit of any scalar map on
                        [0, rho] via the Clenshaw recurrence.

``limit_neg_exp`` is the paper's best performer (Fig. 6): with l odd,
x -> -(1 - x/l)^l is monotone increasing on ALL of R, so it never folds
the spectrum regardless of the spectral radius.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

MatVec = Callable[[jax.Array], jax.Array]
# Internal convention: series bodies call an INDEXED matvec mv(i, u) where i
# is the (traced) position of the matvec within the polynomial evaluation.
# Deterministic operators ignore i; stochastic operators fold i into their
# PRNG key so every monomial factor uses a fresh, independent minibatch
# (required for the unbiasedness argument of paper Sec. 4.3).
IndexedMatVec = Callable[[jax.Array, jax.Array], jax.Array]
# Fused-step convention: fused(u, alpha, beta) -> alpha * (L @ u) + beta * u
# in ONE pass over the panel (repro.core.backend folds the AXPY into the
# Pallas SpMM epilogue).  Every Table-2 recurrence step is such an affine,
# so series that define ``fused_apply_fn`` evaluate with zero extra panel
# round-trips between the matvec and its AXPY.
FusedStep = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class SpectralSeries:
    """A polynomial spectral map with a stable matrix-free evaluator.

    apply_fn(matvec, v) -> S(L) v ;  scalar_fn(lam) -> s(lam).
    The solver-facing operator is ``lambda_star * v - apply(matvec, v)``
    (Eq. 8 reversal: bottom-k of L become top-k).
    """

    name: str
    degree: int
    apply_fn: Callable[[IndexedMatVec, jax.Array], jax.Array]
    scalar_fn: Callable[[jax.Array], jax.Array]
    lambda_star: float = 0.0
    # Optional fused evaluator: (FusedStep, v) -> S(L) v with each
    # recurrence step's affine folded into one backend call.  None =>
    # ``apply_fused`` falls back to the classic recurrence, deriving the
    # plain matvec as fused(u, 1, 0).
    fused_apply_fn: Callable[[FusedStep, jax.Array], jax.Array] | None = None

    def apply(self, matvec: MatVec, v: jax.Array) -> jax.Array:
        return self.apply_fn(lambda i, u: matvec(u), v)

    def apply_fused(self, fused_step: FusedStep, v: jax.Array) -> jax.Array:
        """S(L) v with alpha*Lu+beta*u steps fused into the matvec."""
        if self.fused_apply_fn is None:
            return self.apply_fn(lambda i, u: fused_step(u, 1.0, 0.0), v)
        return self.fused_apply_fn(fused_step, v)

    def apply_reversed_fused(self, fused_step: FusedStep,
                             v: jax.Array) -> jax.Array:
        return self.lambda_star * v - self.apply_fused(fused_step, v)

    def apply_stochastic(self, keyed_matvec, key: jax.Array,
                         v: jax.Array) -> jax.Array:
        """Each internal matvec gets an independent fold_in(key, i) key."""
        return self.apply_fn(
            lambda i, u: keyed_matvec(jax.random.fold_in(key, i), u), v)

    def apply_reversed_stochastic(self, keyed_matvec, key, v):
        return self.lambda_star * v - self.apply_stochastic(keyed_matvec, key, v)

    def scalar(self, lam) -> jax.Array:
        return self.scalar_fn(jnp.asarray(lam))

    def apply_reversed(self, matvec: MatVec, v: jax.Array) -> jax.Array:
        return self.lambda_star * v - self.apply(matvec, v)

    def reversed_scalar(self, lam) -> jax.Array:
        return self.lambda_star - self.scalar(lam)


def identity_series() -> SpectralSeries:
    """No-op series paired with a reversal shift chosen by the caller via
    `with_lambda_star` — the paper's 'identity transformation' baseline."""
    return SpectralSeries(
        name="identity", degree=1,
        apply_fn=lambda mv, v: mv(jnp.zeros((), jnp.int32), v),
        scalar_fn=lambda lam: lam,
        lambda_star=0.0,
    )


def with_lambda_star(s: SpectralSeries, lambda_star: float) -> SpectralSeries:
    return dataclasses.replace(s, lambda_star=float(lambda_star))


def limit_neg_exp(degree: int, scale: float = 1.0) -> SpectralSeries:
    """-(I - s L/l)^l  (Table 2, l odd): u <- u - s (L u)/l, repeated l times.

    `scale` s evaluates f(s lam) — beyond-paper knob to center the dilation
    on the bottom of the spectrum when rho(L) is large.
    """
    if degree % 2 == 0:
        raise ValueError("degree must be odd (paper Table 2: l is odd)")
    c = scale / degree

    def apply_fn(mv: IndexedMatVec, v: jax.Array) -> jax.Array:
        def body(i, u):
            return u - c * mv(i, u)
        return -jax.lax.fori_loop(0, degree, body, v)

    def fused_apply_fn(fs: FusedStep, v: jax.Array) -> jax.Array:
        def body(i, u):
            return fs(u, -c, 1.0)  # u - c (L u), one fused pass
        return -jax.lax.fori_loop(0, degree, body, v)

    def scalar_fn(lam):
        return -((1.0 - c * lam) ** degree)

    return SpectralSeries(
        name=f"limit_neg_exp_d{degree}" + ("" if scale == 1.0 else f"_s{scale:g}"),
        degree=degree, apply_fn=apply_fn, scalar_fn=scalar_fn,
        lambda_star=0.0,  # series < ... <= max 0-ish; top-k solver safe with 0
        fused_apply_fn=fused_apply_fn,
    )


def taylor_neg_exp(degree: int) -> SpectralSeries:
    """-sum_{i=0}^{l} (-L)^i / i!  (Table 2), term recurrence t <- -(L t)/i."""
    if degree % 2 == 0:
        raise ValueError("degree must be odd (paper Table 2: l is odd)")

    def apply_fn(mv: IndexedMatVec, v: jax.Array) -> jax.Array:
        def body(i, carry):
            term, acc = carry
            term = -mv(i, term) / i.astype(v.dtype)
            return term, acc + term
        _, acc = jax.lax.fori_loop(
            1, degree + 1, body, (v, v))
        return -acc

    def fused_apply_fn(fs: FusedStep, v: jax.Array) -> jax.Array:
        def body(i, carry):
            term, acc = carry
            term = fs(term, -1.0 / i.astype(v.dtype), 0.0)  # -(L t)/i
            return term, acc + term
        _, acc = jax.lax.fori_loop(1, degree + 1, body, (v, v))
        return -acc

    def scalar_fn(lam):
        lam = jnp.asarray(lam)
        term = jnp.ones_like(lam)
        acc = jnp.ones_like(lam)
        for i in range(1, degree + 1):
            term = -lam * term / i
            acc = acc + term
        return -acc

    return SpectralSeries(
        name=f"taylor_neg_exp_d{degree}", degree=degree,
        apply_fn=apply_fn, scalar_fn=scalar_fn, lambda_star=0.0,
        fused_apply_fn=fused_apply_fn,
    )


def taylor_log(degree: int, eps: float = 1e-2,
               lambda_star: float = 0.0) -> SpectralSeries:
    """sum_{i=1}^{l} (-1)^{i+1} M^i / i,  M = L + (eps-1) I  (Table 2).

    Convergent only for rho(M) < 1, i.e. spectrum of L within
    (0-ish, 2-eps) — the paper notes it cannot find an accurate series
    over a general Laplacian's full spectrum (Sec. 5.3); we expose it for
    the normalized Laplacian regime where rho <= 2.
    """
    a = eps - 1.0

    def apply_fn(mv: IndexedMatVec, v: jax.Array) -> jax.Array:
        def body(i, carry):
            m, acc = carry  # m = M^{i-1} v
            m = mv(i, m) + a * m  # M^i v
            sign = jnp.where(i % 2 == 1, 1.0, -1.0).astype(v.dtype)
            return m, acc + (sign / i.astype(v.dtype)) * m
        _, acc = jax.lax.fori_loop(1, degree + 1, body, (v, jnp.zeros_like(v)))
        return acc

    def fused_apply_fn(fs: FusedStep, v: jax.Array) -> jax.Array:
        def body(i, carry):
            m, acc = carry
            m = fs(m, 1.0, a)  # M m = L m + a m, one fused pass
            sign = jnp.where(i % 2 == 1, 1.0, -1.0).astype(v.dtype)
            return m, acc + (sign / i.astype(v.dtype)) * m
        _, acc = jax.lax.fori_loop(1, degree + 1, body, (v, jnp.zeros_like(v)))
        return acc

    def scalar_fn(lam):
        lam = jnp.asarray(lam)
        m = jnp.ones_like(lam)
        acc = jnp.zeros_like(lam)
        for i in range(1, degree + 1):
            m = (lam + a) * m
            acc = acc + ((-1.0) ** (i + 1)) / i * m
        return acc

    return SpectralSeries(
        name=f"taylor_log_d{degree}_eps{eps:g}", degree=degree,
        apply_fn=apply_fn, scalar_fn=scalar_fn, lambda_star=lambda_star,
        fused_apply_fn=fused_apply_fn,
    )


def chebyshev(
    fn: Callable[[np.ndarray], np.ndarray],
    degree: int,
    lo: float,
    hi: float,
    name: str = "cheb",
    lambda_star: float | None = None,
) -> SpectralSeries:
    """Beyond-paper: Chebyshev interpolant of `fn` on [lo, hi], applied via
    the Clenshaw recurrence (3 live panels, `degree` matvecs, stable at any
    degree).  Needs far lower degree than Taylor for the same accuracy —
    this repairs the paper's observed Taylor-log failure (Sec. 5.3).
    """
    j = np.arange(degree + 1)
    nodes_t = np.cos(np.pi * (j + 0.5) / (degree + 1))
    x = 0.5 * (hi - lo) * nodes_t + 0.5 * (hi + lo)
    f = fn(x)
    c = np.empty(degree + 1)
    for i in range(degree + 1):
        c[i] = 2.0 / (degree + 1) * np.sum(
            f * np.cos(np.pi * i * (j + 0.5) / (degree + 1)))
    c[0] *= 0.5
    coeffs = jnp.asarray(c, dtype=jnp.float32)
    alpha = 2.0 / (hi - lo)
    beta = -(hi + lo) / (hi - lo)

    def apply_fn(mv: IndexedMatVec, v: jax.Array) -> jax.Array:
        # Clenshaw: b_k = c_k + 2 t(L) b_{k+1} - b_{k+2}
        def t_op(i, u):
            return alpha * mv(i, u) + beta * u

        def body(idx, carry):
            b1, b2 = carry
            k = degree - idx  # runs degree..1
            bk = coeffs[k].astype(v.dtype) * v + 2.0 * t_op(idx, b1) - b2
            return bk, b1
        b1, b2 = jax.lax.fori_loop(
            0, degree, body, (jnp.zeros_like(v), jnp.zeros_like(v)))
        return coeffs[0].astype(v.dtype) * v + t_op(
            jnp.asarray(degree, jnp.int32), b1) - b2

    def fused_apply_fn(fs: FusedStep, v: jax.Array) -> jax.Array:
        # Same Clenshaw recurrence with 2 t(L) b1 = fs(b1, 2a, 2b) — the
        # affine map AND its doubling ride the SpMM epilogue.
        def body(idx, carry):
            b1, b2 = carry
            k = degree - idx
            bk = coeffs[k].astype(v.dtype) * v + fs(b1, 2.0 * alpha,
                                                    2.0 * beta) - b2
            return bk, b1
        b1, b2 = jax.lax.fori_loop(
            0, degree, body, (jnp.zeros_like(v), jnp.zeros_like(v)))
        return coeffs[0].astype(v.dtype) * v + fs(b1, alpha, beta) - b2

    def scalar_fn(lam):
        lam = jnp.asarray(lam)
        t = alpha * lam + beta
        b1 = jnp.zeros_like(lam)
        b2 = jnp.zeros_like(lam)
        for k in range(degree, 0, -1):
            b1, b2 = coeffs[k] + 2.0 * t * b1 - b2, b1
        return coeffs[0] + t * b1 - b2

    if lambda_star is None:
        lambda_star = float(np.max(f)) * 1.01 + 1e-6
    return SpectralSeries(
        name=f"{name}_d{degree}", degree=degree,
        apply_fn=apply_fn, scalar_fn=scalar_fn, lambda_star=lambda_star,
        fused_apply_fn=fused_apply_fn,
    )


def cheb_neg_exp(degree: int, rho: float, tau: float = 1.0) -> SpectralSeries:
    """Chebyshev fit of -e^{-tau x} on [0, rho]."""
    return chebyshev(
        lambda x: -np.exp(-tau * x), degree, 0.0, rho,
        name=f"cheb_neg_exp_t{tau:g}", lambda_star=0.0)


def cheb_log(degree: int, rho: float, eps: float = 1e-2) -> SpectralSeries:
    """Chebyshev fit of log(x + eps) on [0, rho] — the stable series form
    of the paper's best EXACT transform, which its Taylor series could not
    reach (Sec. 5.3)."""
    return chebyshev(
        lambda x: np.log(x + eps), degree, 0.0, rho,
        name=f"cheb_log_eps{eps:g}",
        lambda_star=float(np.log(rho + eps)) * 1.01 + 1e-3)


TABLE2_SERIES = {
    "taylor_log": taylor_log,
    "taylor_neg_exp": taylor_neg_exp,
    "limit_neg_exp": limit_neg_exp,
}
