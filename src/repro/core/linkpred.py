"""Common-neighbors link prediction (paper App. A.1).

Pipeline: drop edges w.p. p, score the missing pairs by their number of
common neighbors (Martinez et al. 2016), normalize scores over missing
pairs into probabilities, and return the completed WEIGHTED graph whose
Laplacian SPED then clusters (Fig. 5 setting).
"""
from __future__ import annotations

import numpy as np

from repro.core.laplacian import EdgeList, make_edge_list


def common_neighbors_scores(adj: np.ndarray, pairs: np.ndarray) -> np.ndarray:
    """score(i, j) = |N(i) ∩ N(j)| computed via the squared adjacency."""
    a2 = adj @ adj
    return a2[pairs[:, 0], pairs[:, 1]]


def complete_graph(g: EdgeList, drop_prob: float = 0.2, seed: int = 0) -> EdgeList:
    """Drop edges, predict them back with common-neighbors probabilities."""
    rng = np.random.default_rng(seed)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weight)
    n = g.num_nodes

    keep = rng.random(len(src)) >= drop_prob
    kept = np.stack([src[keep], dst[keep]], axis=1)
    dropped = np.stack([src[~keep], dst[~keep]], axis=1)

    adj = np.zeros((n, n), dtype=np.float64)
    adj[kept[:, 0], kept[:, 1]] = w[keep]
    adj[kept[:, 1], kept[:, 0]] = w[keep]

    if len(dropped) == 0:
        return make_edge_list(kept, n, weights=w[keep])

    scores = common_neighbors_scores(adj, dropped).astype(np.float64)
    total = scores.sum()
    if total <= 0:
        probs = np.full(len(dropped), 1.0 / len(dropped))
    else:
        probs = scores / total
    # scale so predicted mass matches the dropped mass (keeps the degree
    # distribution comparable to the original graph)
    pred_w = probs * float(w[~keep].sum())

    all_edges = np.concatenate([kept, dropped], axis=0)
    all_w = np.concatenate([w[keep], pred_w])
    pos = all_w > 1e-12
    return make_edge_list(all_edges[pos], n, weights=all_w[pos])
