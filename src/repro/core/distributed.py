"""Distributed SPED: shard_map-parallel operators (paper Sec. 4.3's
"d graph walkers, in parallel" + the stochastic optimization model).

Parallelization axes:
  * EDGES over the ("pod", "data") mesh axes — each device owns a shard
    of the incidence rows; a Laplacian matvec is a local edge-wise
    gather/scatter followed by ONE psum of the (n, k) panel.  This is the
    same collective footprint as data-parallel gradient aggregation, so
    the LM substrate's mesh/runtime is reused unchanged.
  * WALKERS over the same axes — each device runs an independent batch of
    incidence-graph walks (vmapped), contributions are psum-averaged.
    Any subset of walkers yields an unbiased estimate (Sec. 4.3), which
    is what makes the scheme straggler-tolerant: a backup-task scheme can
    drop slow walkers' contributions without bias (DESIGN.md Sec. 5).

The eigenvector panel V (n, k) is replicated; for very large n it can be
node-sharded over "model" (see shard_v_spec) — the solver's QR then runs
on gathered panels, which is fine for k <= a few hundred.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core import laplacian as lap_mod
from repro.core.laplacian import EdgeIncidence, EdgeList
from repro.core.series import SpectralSeries
from repro.core import walks as walks_mod


def pad_edges_for_mesh(g: EdgeList, num_shards: int) -> EdgeList:
    """Pad with inert zero-weight edges so the edge buffer divides evenly
    across shards.  Accepts already capacity-padded buffers (e.g. from the
    streaming graph store) — padding slots stay inert through the shards'
    gather/scatter since their weight is zero."""
    e = g.num_edges
    return lap_mod.pad_edge_list(g, e + ((-e) % num_shards))


def num_edge_shards(mesh: Mesh, edge_axes=("data",)) -> int:
    """Product of the mesh's edge-axis sizes — the shard count every
    edge buffer (and per-shard blocking) must divide into."""
    num_shards = 1
    for a in edge_axes:
        num_shards *= mesh.shape[a]
    return num_shards


def sharded_laplacian_matvec(mesh: Mesh, edge_axes=("data",),
                             backend: str = "auto",
                             num_nodes: int | None = None):
    """Returns matvec(src, dst, w, v) -> L @ v with edges sharded over
    `edge_axes` and v replicated; one psum over the edge axes.

    ``backend`` (repro.core.backend) swaps the PER-SHARD local matvec —
    jnp gather/scatter vs the Pallas one-hot incidence SpMM — while the
    psum contract (one (n, k) panel reduction per matvec) is unchanged.
    The panel is replicated, so the per-shard kernel sees the full n and
    the one-hot VMEM guard (``resolve_for_arrays``) applies: past the
    node limit this raw-array form degrades to segment — build a
    :class:`~repro.kernels.edge_spmm.ops.ShardedNodeBlocking` and use
    :func:`sharded_blocked_matvec` to keep the pallas path instead.
    Pass ``num_nodes`` to resolve that guard up front — it also keeps
    shard_map's replication check on when the resolution lands on
    segment; without it the check must be disabled pessimistically
    (pallas_call has no replication rule).
    """
    from repro.core import backend as backend_mod

    spec_e = P(edge_axes)
    spec_v = P()
    b = backend_mod.resolve_backend(backend)
    if num_nodes is not None:
        b = backend_mod.resolve_for_arrays(b, num_nodes)
    interp = backend_mod.kernel_interpret()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, spec_v),
        out_specs=spec_v,
        # the explicit psum below makes the output replication manifest
        check_vma=b != "pallas")
    def mv(src, dst, w, v):
        out = backend_mod.edge_arrays_matvec_fn(
            src, dst, w, b, num_nodes=v.shape[0], interpret=interp)(v)
        return jax.lax.psum(out, edge_axes)

    return mv


def sharded_blocked_matvec(mesh: Mesh, blocking, edge_axes=("data",),
                           interpret: bool | None = None):
    """Returns matvec(v) -> L @ v through PER-SHARD node-blocked pallas
    kernels — the sharded path that scales past ``ONE_HOT_NODE_LIMIT``.

    ``blocking`` is a :class:`~repro.kernels.edge_spmm.ops.
    ShardedNodeBlocking` (build with ``backend.sharded_blocking_for``):
    its stacked per-shard arrays are partitioned over ``edge_axes`` so
    each device runs the node-blocked kernel on ITS half-edge buckets
    only — a (block_n, k) panel slice resident per grid step, exactly
    like the single-device kernel — and the per-shard
    ``deg_s * v - A_s v`` outputs psum to the full ``L v``.
    """
    from repro.core import backend as backend_mod

    if blocking.num_shards != num_edge_shards(mesh, edge_axes):
        raise ValueError(
            f"blocking has {blocking.num_shards} shards but the mesh's "
            f"{edge_axes} axes hold {num_edge_shards(mesh, edge_axes)}")
    interp = (backend_mod.kernel_interpret() if interpret is None
              else interpret)
    from repro.kernels.edge_spmm import ops as es_ops

    spec_s = P(edge_axes)  # leading shard axis over the edge axes
    static = blocking.statics

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_s, spec_s, spec_s, spec_s, spec_s, P()),
        out_specs=P(),
        check_vma=False)  # pallas_call has no replication rule
    def mv(u_local, other, w, cb, deg, v):
        local = es_ops.shard_local_blocking(u_local, other, w, cb, deg,
                                            **static)
        out = es_ops.edge_spmm_blocked(local, v, interpret=interp)
        return jax.lax.psum(out, edge_axes)

    return lambda v: mv(blocking.u_local, blocking.other, blocking.weight,
                        blocking.chunk_block, blocking.deg, v)


def distributed_series_operator(
    mesh: Mesh,
    g: EdgeList,
    series: SpectralSeries,
    edge_axes=("data",),
    backend: str = "auto",
    block_n: int | None = None,
):
    """Deterministic distributed operator: V -> (lambda* I - S(L)) V.

    Edges are padded + sharded once, and the WHOLE series runs as one
    shard_mapped program: each of the `degree` matvecs is a per-shard
    kernel (per ``backend``) followed by one psum of the (n, k) panel,
    and the series AXPY applies post-psum (alpha rides the linear psum;
    beta must apply exactly once, so the kernel-epilogue fusion is a
    single-device luxury the sharded program trades for the collective).

    On the pallas backend, graphs past ``ONE_HOT_NODE_LIMIT`` (or an
    explicit ``block_n``) get PER-SHARD node blockings — the sharded
    path no longer degrades to segment on large graphs.
    """
    from repro.core import backend as backend_mod

    num_shards = num_edge_shards(mesh, edge_axes)
    gp = pad_edges_for_mesh(g, num_shards)
    b = backend_mod.resolve_backend(backend)
    blocking = None
    if b == "pallas" and (block_n is not None
                          or g.num_nodes > backend_mod.ONE_HOT_NODE_LIMIT):
        blocking = backend_mod.sharded_blocking_for(
            gp, num_shards, block_n=block_n)
    interp = backend_mod.kernel_interpret()
    spec_e = P(edge_axes)

    if blocking is not None:
        from repro.kernels.edge_spmm import ops as es_ops

        static = blocking.statics

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(spec_e, spec_e, spec_e, spec_e, spec_e, P()),
            out_specs=P(),
            check_vma=False)  # pallas_call has no replication rule
        def series_program(u_local, other, w, cb, deg, v):
            local = es_ops.shard_local_blocking(u_local, other, w, cb,
                                                deg, **static)

            def fused(u, alpha, beta):
                lu = jax.lax.psum(
                    es_ops.edge_spmm_blocked(local, u, interpret=interp),
                    edge_axes)
                return alpha * lu + beta * u

            return series.apply_reversed_fused(fused, v)

        return lambda v: series_program(
            blocking.u_local, blocking.other, blocking.weight,
            blocking.chunk_block, blocking.deg, v)

    bb = backend_mod.resolve_for_arrays(b, g.num_nodes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, P()),
        out_specs=P(),
        check_vma=bb != "pallas")
    def series_program(src, dst, w, v):
        local_mv = backend_mod.edge_arrays_matvec_fn(
            src, dst, w, bb, num_nodes=v.shape[0], interpret=interp)

        def fused(u, alpha, beta):
            lu = jax.lax.psum(local_mv(u), edge_axes)
            return alpha * lu + beta * u

        return series.apply_reversed_fused(fused, v)

    return lambda v: series_program(gp.src, gp.dst, gp.weight, v)


def distributed_solve(
    mesh: Mesh,
    g: EdgeList,
    series: SpectralSeries,
    cfg,
    edge_axes=("data",),
    backend: str = "auto",
    block_n: int | None = None,
    v_star=None,
    init_v=None,
):
    """One-shot distributed solve: the whole-series shard_mapped
    operator driven by THE unified solve loop
    (:func:`repro.core.program.run_program`) — the same step
    construction the one-shot, streaming, and sharded tick paths run.

    ``cfg`` is a :class:`repro.core.solvers.SolverConfig`; returns
    ``(state, trace)`` exactly like ``run_solver``.
    """
    from repro.core import program

    op = distributed_series_operator(
        mesh, g, series, edge_axes=edge_axes, backend=backend,
        block_n=block_n)
    return program.run_program(op, g.num_nodes, cfg, v_star=v_star,
                               init_v=init_v)


def distributed_minibatch_operator(
    mesh: Mesh,
    g: EdgeList,
    series: SpectralSeries,
    batch_edges_per_device: int,
    edge_axes=("data",),
):
    """Stochastic distributed operator (the paper's scaling model):
    every device samples an INDEPENDENT minibatch of edges per inner
    matvec; the psum'd average stays unbiased and variance shrinks
    linearly in the device count.
    """
    e = g.num_edges
    spec_r = P(edge_axes)  # per-device keys stacked on the edge axes

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_r, P()),
        out_specs=P())
    def mb_mv(keys, v):
        key = keys[0]
        sel = jax.random.randint(key, (batch_edges_per_device,), 0, e)
        w = g.weight[sel] * (e / batch_edges_per_device)
        diff = v[g.src[sel]] - v[g.dst[sel]]
        out = jnp.zeros_like(v)
        out = out.at[g.src[sel]].add(w[:, None] * diff)
        out = out.at[g.dst[sel]].add(-w[:, None] * diff)
        return jax.lax.pmean(out, edge_axes)

    num_shards = num_edge_shards(mesh, edge_axes)

    def op(key: jax.Array, v: jax.Array) -> jax.Array:
        def keyed_mv(k, u):
            dev_keys = jax.random.split(k, num_shards)
            return mb_mv(dev_keys, u)
        return series.apply_reversed_stochastic(keyed_mv, key, v)

    return op


def distributed_walk_operator(
    mesh: Mesh,
    g: EdgeList,
    inc: EdgeIncidence,
    coeffs: tuple[float, ...],
    lambda_star: float,
    walkers_per_device: int,
    edge_axes=("data",),
    mode: str = "importance",
):
    """Paper Sec. 4.3 fully realized: d devices x W walkers, in parallel.

    Each device samples walkers_per_device independent incidence-graph
    walks and computes its local power estimates; pmean over devices
    averages the unbiased per-device estimates.
    """
    deg = len(coeffs) - 1
    spec_r = P(edge_axes)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec_r, P()), out_specs=P(),
        check_vma=False)  # scan carries mix varying/unvarying init values
    def walk_apply(keys, v):
        key = keys[0]
        wb = walks_mod.sample_walks(key, inc, walkers_per_device, max(deg, 2))
        acc = coeffs[0] * v
        for p in range(1, deg + 1):
            est = walks_mod.estimate_power_matvec(
                wb, g, inc, p, v, mode=mode,
                key=jax.random.fold_in(key, 1000 + p) if mode == "rejection"
                else None)
            acc = acc + coeffs[p] * est
        return jax.lax.pmean(acc, edge_axes)

    num_shards = num_edge_shards(mesh, edge_axes)

    def op(key: jax.Array, v: jax.Array) -> jax.Array:
        dev_keys = jax.random.split(key, num_shards)
        return lambda_star * v - walk_apply(dev_keys, v)

    return op
