"""Baselines from the paper's related-work section (App. B), implemented
so SPED's comparisons aren't only against the identity transform:

* **Bethe Hessian** (Saade et al. 2014): H(r) = (r^2 - 1) I - r A + D,
  r = sqrt(average branching ratio).  Spectral clustering for SBM graphs
  uses the eigenvectors of H's NEGATIVE eigenvalues; detects communities
  down to the detectability threshold where the plain Laplacian fails.
* **Shift-and-invert power iteration** (Garber et al. 2016): find the
  bottom eigenvector of L as the TOP eigenvector of (L + shift I)^{-1},
  with the inverse applied via conjugate-gradient solves (matrix-free,
  like SPED — but each operator application costs a CG solve instead of
  a fixed polynomial).
* **Lanczos** (reference eigensolver): exact-arithmetic ground truth for
  graphs too large for dense eigh; used by tests/benchmarks as the
  oracle at n >~ 4096.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laplacian import EdgeList, adjacency_dense, degrees


# --------------------------------------------------------------------------
# Bethe Hessian (Saade et al. 2014)
# --------------------------------------------------------------------------

def bethe_hessian_dense(g: EdgeList, r: float | None = None) -> jax.Array:
    """H(r) = (r^2 - 1) I - r A + D.  Default r = sqrt(sum d_i^2 / sum d_i
    - 1) (the average branching ratio estimator from the paper)."""
    a = adjacency_dense(g)
    d = degrees(g)
    if r is None:
        r = float(jnp.sqrt(jnp.sum(d * d) / jnp.maximum(jnp.sum(d), 1e-9)
                           - 1.0))
    n = g.num_nodes
    return (r * r - 1.0) * jnp.eye(n) - r * a + jnp.diag(d), r


def bethe_hessian_cluster(g: EdgeList, num_clusters: int, seed: int = 0):
    """Spectral clustering with the Bethe Hessian's bottom eigenvectors
    (the negative-eigenvalue subspace carries community structure)."""
    from repro.core.kmeans import kmeans
    h, r = bethe_hessian_dense(g)
    lam, vecs = jnp.linalg.eigh(h)
    emb = vecs[:, :num_clusters]
    emb = emb / jnp.maximum(jnp.linalg.norm(emb, axis=1, keepdims=True),
                            1e-12)
    res = kmeans(jax.random.PRNGKey(seed), emb, num_clusters)
    return res.labels, {"r": r, "negative_eigs": int(jnp.sum(lam < 0))}


# --------------------------------------------------------------------------
# Shift-and-invert via CG (Garber et al. 2016)
# --------------------------------------------------------------------------

def cg_solve(matvec, b, x0=None, iters: int = 50, tol: float = 1e-6):
    """Conjugate gradient for SPD matvec; panel-ready ((n, k) rhs)."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    p = r
    rs = jnp.sum(r * r, axis=0)

    def body(i, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        alpha = rs / jnp.maximum(jnp.sum(p * ap, axis=0), 1e-30)
        x = x + alpha[None, :] * p
        r = r - alpha[None, :] * ap
        rs_new = jnp.sum(r * r, axis=0)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = r + beta[None, :] * p
        return x, r, p, rs_new

    x, r, p, rs = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
    return x


def shift_invert_operator(matvec, shift: float, cg_iters: int = 50):
    """V -> (L + shift I)^{-1} V via CG — the Garber et al. preconditioner
    as a solver-compatible operator (top-k of this = bottom-k of L)."""

    def shifted(v):
        return matvec(v) + shift * v

    def op(v):
        return cg_solve(shifted, v, iters=cg_iters)

    return op


# --------------------------------------------------------------------------
# Lanczos reference eigensolver
# --------------------------------------------------------------------------

def lanczos_bottom_k(matvec, n: int, k: int, iters: int = 0,
                     seed: int = 0):
    """Bottom-k eigenpairs of a symmetric operator via the Lanczos
    tridiagonalization with full reorthogonalization (host-precision
    reference; not the scalable path — that's SPED's job)."""
    iters = iters or min(n, max(4 * k, 64))
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(n,))
    q /= np.linalg.norm(q)
    qs = [q]
    alphas, betas = [], []
    for j in range(iters):
        w = np.asarray(matvec(jnp.asarray(qs[-1], jnp.float32)),
                       dtype=np.float64)
        alpha = float(w @ qs[-1])
        w = w - alpha * qs[-1] - (betas[-1] * qs[-2] if betas else 0.0)
        # full reorthogonalization (stability)
        for qq in qs:
            w = w - (w @ qq) * qq
        beta = float(np.linalg.norm(w))
        alphas.append(alpha)
        if beta < 1e-12 or j == iters - 1:
            break
        betas.append(beta)
        qs.append(w / beta)
    t = np.diag(alphas) + np.diag(betas, 1) + np.diag(betas, -1)
    lam, s = np.linalg.eigh(t)
    qmat = np.stack(qs, axis=1)  # (n, m)
    vecs = qmat @ s[:, :k]
    vecs /= np.linalg.norm(vecs, axis=0, keepdims=True)
    return jnp.asarray(lam[:k], jnp.float32), jnp.asarray(vecs, jnp.float32)
