"""Solver-facing operator builders.

An "operator" is a function V -> A @ V (optionally keyed for stochastic
estimates) where A = lambda* I - S(L) is the transformed + reversed
Laplacian (Eqs. 8, Table 2).  This module wires together:

  laplacian matvec  x  spectral series  x  estimation mode

into the matvec consumed by :mod:`repro.core.solvers`.

Estimation modes:
  * exact dense    — L as a dense matrix (small graphs, paper Sec. 5)
  * edge matvec    — matrix-free full-batch, O(E k) per matvec
  * minibatch      — unbiased stochastic minibatch of edges per matvec
                     (the paper's stochastic optimization model, Sec. 3)
  * walks          — the Sec. 4.3 random-walk estimator of L^l, see
                     :mod:`repro.core.walks`

Every constructor accepts ``backend`` (``"auto"|"segment"|"pallas"``,
see :mod:`repro.core.backend`): the inner Laplacian matvec runs either
as the jnp gather/scatter or as the Pallas incidence-SpMM kernels, with
series steps fused into the kernel epilogue on the pallas path.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import backend as backend_mod
from repro.core import laplacian as lap
from repro.core import metrics
from repro.core.series import SpectralSeries

MatVec = Callable[[jax.Array], jax.Array]


def dense_matvec(l_mat: jax.Array) -> MatVec:
    return lambda v: l_mat @ v


def dilated_operator_arrays(src: jax.Array, dst: jax.Array, w: jax.Array,
                            c, degree: int) -> MatVec:
    """``V -> (I - c L)^degree V`` on raw edge arrays — the dilated
    reversed operator of one streaming session (the paper's
    limit_neg_exp series with lambda* = 0, unit-normalized).  ``c`` may
    be traced (per-session scales, one program); ``degree`` is static.
    THE single definition of this operator form: the streaming
    service's residual checks and every tick program's segment source
    (`core.program`) close over it.
    """
    def opv(v: jax.Array) -> jax.Array:
        def body(_, u):
            return u - c * lap.edge_matvec_arrays(src, dst, w, u)
        return jax.lax.fori_loop(0, degree, body, v)

    return opv


@functools.partial(jax.jit, static_argnames=("degree",))
def dilated_matvec_arrays(src, dst, w, v, c, degree: int):
    """Jitted ``(I - c L)^degree V`` (was ``stream.service._op_apply``)."""
    return dilated_operator_arrays(src, dst, w, c, degree)(v)


@functools.partial(jax.jit, static_argnames=("degree",))
def dilated_panel_residual(src, dst, w, v, c, degree: int):
    """Panel residual under the dilated reversed operator (was
    ``stream.service._op_residual``)."""
    return metrics.operator_residual(
        dilated_operator_arrays(src, dst, w, c, degree), v)


def edge_matvec(g: lap.EdgeList, backend: str = "auto",
                blocking: backend_mod.NodeBlocking | None = None) -> MatVec:
    """V -> L @ V on the selected backend (node-blocked kernel auto-built
    for pallas when n exceeds the one-hot VMEM limit)."""
    return backend_mod.laplacian_matvec_fn(g, backend, blocking)


def series_operator(series: SpectralSeries, matvec: MatVec,
                    fused_step: backend_mod.FusedStep | None = None) -> MatVec:
    """V -> (lambda* I - S(L)) V, deterministic.

    ``fused_step`` (from :func:`repro.core.backend.fused_step_fn`)
    switches the series onto its fused evaluator — each recurrence step
    is one kernel call with the AXPY in the epilogue.
    """
    if fused_step is not None:
        return lambda v: series.apply_reversed_fused(fused_step, v)
    return lambda v: series.apply_reversed(matvec, v)


def edge_series_operator(
    g: lap.EdgeList,
    series: SpectralSeries,
    backend: str = "auto",
    blocking: backend_mod.NodeBlocking | None = None,
) -> MatVec:
    """The exact_edges operator: series over the edge-list matvec on the
    selected backend (fused series steps on pallas)."""
    fused = backend_mod.fused_step_fn(g, backend, blocking)
    if fused is not None:
        return series_operator(series, None, fused_step=fused)
    return series_operator(series, edge_matvec(g, backend="segment"))


def exact_operator(series_or_transform, l_mat: jax.Array) -> MatVec:
    """Exact f(L) via eigh — the paper's green 'exact' curves.

    Accepts either a SpectralSeries (uses its scalar map) or a
    transforms.Transform.
    """
    lam, vecs = jnp.linalg.eigh(l_mat)
    if hasattr(series_or_transform, "reversed_scalar"):
        f_lam = series_or_transform.reversed_scalar(lam)
    else:  # transforms.Transform
        rho = lam[-1]
        f_lam = series_or_transform.lambda_star(rho) - series_or_transform.scalar(lam)
    a = (vecs * f_lam[None, :]) @ vecs.T

    return lambda v: a @ v


def minibatch_operator(
    g: lap.EdgeList,
    series: SpectralSeries,
    batch_edges: int,
    backend: str = "auto",
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Stochastic operator: each inner Laplacian matvec uses an
    independent uniform minibatch of edges (unbiased for L, and since
    successive matvecs use independent batches, each monomial estimate
    E[L_b1 ... L_bi] = L^i is unbiased — the product of independent
    unbiased factors).

    The minibatch is re-drawn per matvec, so there is no precomputed
    node blocking: the pallas path uses the one-hot incidence kernel
    (which IS the minibatch kernel of DESIGN.md Sec. 3) and falls back
    to segment beyond its n limit.  Both backends draw the SAME edges
    for the same key — only the SpMM implementation differs.

    Returns op(key, V).
    """
    e = g.num_edges
    b = backend_mod.resolve_for_arrays(backend, g.num_nodes)
    interp = backend_mod.kernel_interpret()
    scale = e / batch_edges

    def keyed_mv(k: jax.Array, u: jax.Array) -> jax.Array:
        sel = jax.random.randint(k, (batch_edges,), 0, e)
        if b == "pallas":
            from repro.kernels.edge_spmm import ops as es_ops
            return es_ops.edge_spmm(
                g.src[sel], g.dst[sel], g.weight[sel] * scale, u,
                interpret=interp)
        return lap.minibatch_laplacian_matvec(
            g.src[sel], g.dst[sel], g.weight[sel], u, e)

    def op(key: jax.Array, v: jax.Array) -> jax.Array:
        return series.apply_reversed_stochastic(keyed_mv, key, v)

    return op


def scaled_series_for_graph(
    g: lap.EdgeList, series_fn, degree: int, target_radius: float = 1.0,
    rho: float | None = None,
):
    """Beyond-paper helper: pre-scale L by target_radius/rho so a fixed-
    degree series stays accurate regardless of the graph's max degree —
    this addresses the paper's Fig. 4 failure mode (series under-resolved
    when deg* blows up).  Scaling L preserves eigenvectors and ORDER, so
    it is itself an eigenvector-preserving transform.

    `rho` takes a probed spectral-radius estimate (repro.spectral); the
    Gershgorin-style `spectral_radius_upper_bound` remains the default —
    it over-estimates by ~2x on dense graphs, which silently halves the
    effective dilation; prefer `planned_operator` when the probe cost
    (a few dozen matvecs) is affordable.
    """
    if rho is None:
        rho = float(lap.spectral_radius_upper_bound(g))
    scale = target_radius / max(rho, 1e-30)
    return series_fn(degree, scale=scale) if "scale" in series_fn.__code__.co_varnames \
        else series_fn(degree)


def planned_operator(
    g: lap.EdgeList,
    k: int,
    key: jax.Array | None = None,
    budget: int = 96,
    estimation: str = "exact_edges",
    batch_edges: int = 1024,
    num_probes: int = 4,
    num_steps: int = 24,
    backend: str = "auto",
):
    """Probe the graph's spectrum and build an auto-tuned solver operator.

    SLQ-probes lambda_max and the bottom-edge eigengap (a few dozen
    single-vector matvecs), plans transform family / degree / strength
    via repro.spectral, and wires the tuned series into the requested
    estimation mode.  Returns (operator, DilationPlan); the operator is
    deterministic for "exact_edges" and keyed op(key, V) for
    "minibatch".  `budget` caps the matvecs one operator application may
    spend (the series degree).  ``backend`` selects the matvec kernels
    for BOTH the probes and the solve operator.
    """
    from repro import spectral  # deferred: spectral builds on core

    probe, plan = spectral.probe_and_plan(
        g, k=k, key=key, budget=budget,
        num_probes=num_probes, num_steps=num_steps, backend=backend)
    del probe
    s = spectral.series_from_plan(plan)
    if estimation == "exact_edges":
        return edge_series_operator(g, s, backend=backend), plan
    if estimation == "minibatch":
        return minibatch_operator(g, s, batch_edges, backend=backend), plan
    raise ValueError(f"unknown estimation mode {estimation!r}")
