"""SPED core: stochastic parallelizable eigengap dilation (the paper's
primary contribution) plus the spectral-clustering pipeline around it."""
from repro.core.laplacian import (  # noqa: F401
    EdgeIncidence,
    EdgeList,
    adjacency_dense,
    build_edge_incidence,
    degrees,
    edge_inner_product,
    edge_matvec_arrays,
    incidence_matrix,
    laplacian_dense,
    laplacian_matvec,
    make_edge_list,
    minibatch_laplacian_matvec,
    pad_edge_list,
    normalized_laplacian_dense,
    spectral_radius_upper_bound,
)
from repro.core.series import (  # noqa: F401
    SpectralSeries,
    cheb_log,
    cheb_neg_exp,
    chebyshev,
    identity_series,
    limit_neg_exp,
    taylor_log,
    taylor_neg_exp,
    with_lambda_star,
)
from repro.core.backend import (  # noqa: F401
    BACKENDS,
    ModelShardedBlocking,
    NodeBlocking,
    build_model_sharded_blocking,
    build_node_blocking,
    kernel_interpret,
    resolve_backend,
)
from repro.core.solvers import (  # noqa: F401
    SolverConfig,
    SolverState,
    Trace,
    init_from_panel,
    init_state,
    make_step_fn,
    mu_eg_step,
    mu_eg_step_from_gram,
    mu_eg_step_fused,
    oja_step,
    panel_gram2k,
    run_solver,
    steps_to_streak,
    steps_to_tolerance,
)
from repro.core.clustering import (  # noqa: F401
    ClusteringConfig,
    build_series,
    exact_cluster_reference,
    spectral_cluster,
)
from repro.core.program import (  # noqa: F401
    StepSchedule,
    apply_solver_step,
    build_tick_program,
    count_psums,
    run_chunk,
    run_program,
    schedule_degrees,
)
