"""Eigenvector-preserving spectrum transformations (paper Sec. 4.1, Table 2).

A transform maps the graph Laplacian L to f(L) with the SAME eigenvectors
and monotonically transformed eigenvalues (monotone at least below the
cutoff of interest), followed by the spectrum reversal of Eq. (8),
``L^- = lambda* I - f(L)``, so bottom-k eigenvectors of L become top-k of
the reversed operator.

Two evaluation modes:
  * ``exact_*``: via eigendecomposition — the paper's "exact" curves
    (green).  Only for evaluation/small problems; O(n^3).
  * series approximations live in :mod:`repro.core.series` and are
    matrix-free (the deployable path).

Scalar spectral maps are exposed so tests can verify monotonicity and gap
dilation analytically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Transform:
    """A named eigenvector-preserving spectral transform.

    scalar(lam) applies f to eigenvalues; lambda_star is the reversal
    shift of Eq. (8) guaranteeing lambda* >= f(lambda_max) so that the
    reversed spectrum is non-negative and bottom-k -> top-k.
    """

    name: str
    scalar: Callable[[jax.Array], jax.Array]
    # reversal shift; callable of the (upper bound on) spectral radius of L
    lambda_star: Callable[[float], float]

    def exact_matrix(self, l_mat: jax.Array) -> jax.Array:
        """f(L) via eigendecomposition (paper's exact baseline)."""
        lam, v = jnp.linalg.eigh(l_mat)
        return (v * self.scalar(lam)[None, :]) @ v.T

    def exact_reversed(self, l_mat: jax.Array, rho: float) -> jax.Array:
        """lambda* I - f(L): top-k of this = bottom-k of L."""
        n = l_mat.shape[0]
        return self.lambda_star(rho) * jnp.eye(n, dtype=l_mat.dtype) - \
            self.exact_matrix(l_mat)


def identity_transform() -> Transform:
    return Transform(
        name="identity",
        scalar=lambda lam: lam,
        lambda_star=lambda rho: float(rho) * 1.01,
    )


def neg_exp_transform() -> Transform:
    """f(L) = -e^{-L} (paper Sec. 4.2): shrinks large eigenvalues relative
    to small ones; max eigenvalue < 0 so lambda* = 0 works and the
    reversed spectral radius is <= 1."""
    return Transform(
        name="neg_exp",
        scalar=lambda lam: -jnp.exp(-lam),
        lambda_star=lambda rho: 0.0,
    )


def log_transform(eps: float = 1e-2) -> Transform:
    """f(L) = log(L + eps I) (Table 2).  Strongly dilates the bottom gaps."""
    return Transform(
        name=f"log_eps{eps:g}",
        scalar=lambda lam: jnp.log(lam + eps),
        lambda_star=lambda rho: float(jnp.log(rho + eps)) * 1.01 + 1e-3,
    )


def shifted_inverse_transform(shift: float = 1e-1) -> Transform:
    """f(L) = -(L + shift I)^{-1} — shift-and-invert analogue (App. B).

    Included as a strong classical baseline: also eigenvector-preserving
    and monotone, but requires a linear solve rather than matvecs.
    """
    return Transform(
        name=f"shift_inv{shift:g}",
        scalar=lambda lam: -1.0 / (lam + shift),
        lambda_star=lambda rho: 0.0,
    )


DEFAULT_TRANSFORMS = {
    "identity": identity_transform,
    "neg_exp": neg_exp_transform,
    "log": log_transform,
    "shift_inv": shifted_inverse_transform,
}


def eigengap_ratio(lams: jax.Array, k: int) -> jax.Array:
    """Convergence-relevant ratio max_i<=k  rho / g_i  (paper Sec. 3).

    lams must be sorted ascending; rho is spectral RANGE of the reversed
    operator (max - min) and g_i the consecutive gaps among the bottom
    k+1 eigenvalues.  Lower is better (fewer solver steps).
    """
    rho = lams[-1] - lams[0]
    gaps = lams[1: k + 1] - lams[:k]
    return rho / jnp.maximum(jnp.min(gaps), 1e-30)


def dilation_factor(lams: jax.Array, tf: Transform, k: int) -> jax.Array:
    """How much tf improves the ratio: ratio(L) / ratio(f(L)).  > 1 is a win."""
    before = eigengap_ratio(lams, k)
    after = eigengap_ratio(jnp.sort(tf.scalar(lams)), k)
    return before / after
