"""Graph generators used in the paper's experiments (Sec. 5, App. A).

- three_room_mdp: Fig. 1 grid world (3 rooms joined by small doors) whose
  state-transition graph yields proto-value functions (Sec. 5.3).
- clique_graph: k cliques joined by 0..25 random short-circuit edges
  (Sec. 5.4).
- sbm_graph: stochastic block model (referenced via Saade et al. / SBM
  discussion in App. B) — used for property tests.

Generators are host-side numpy (graph construction is data prep, not a
jit region) and return EdgeList plus ground-truth cluster labels where
defined.
"""
from __future__ import annotations

import numpy as np

from repro.core.laplacian import EdgeList, make_edge_list


def three_room_mdp(s: int = 2, h: int = 10):
    """3-room grid world, 10s+1 cells tall, 30s+1 cells wide (paper Fig. 1).

    Two interior walls split the width into 3 equal rooms; each wall has a
    door of height ceil((10s+1)/h) centered vertically.  Nodes are cells,
    undirected edges are the 4-neighbour transitions.

    Returns (EdgeList, labels) with labels = room index per cell.
    """
    height = 10 * s + 1
    width = 30 * s + 1
    room_w = width // 3  # wall sits between columns room_w-1 / room_w (x2)
    door_h = max(1, (height + h - 1) // h)
    door_lo = (height - door_h) // 2
    door_hi = door_lo + door_h  # exclusive

    def node(r, c):
        return r * width + c

    edges = []
    for r in range(height):
        for c in range(width):
            # vertical edge down
            if r + 1 < height:
                edges.append((node(r, c), node(r + 1, c)))
            # horizontal edge right, unless crossing a wall outside the door
            if c + 1 < width:
                crossing_wall = (c + 1) % room_w == 0 and (c + 1) // room_w in (1, 2) \
                    and (c + 1) < width
                if crossing_wall and not (door_lo <= r < door_hi):
                    continue
                edges.append((node(r, c), node(r, c + 1)))
    labels = np.zeros((height * width,), dtype=np.int32)
    for r in range(height):
        for c in range(width):
            labels[node(r, c)] = min(c // room_w, 2)
    g = make_edge_list(np.asarray(edges, dtype=np.int32), height * width)
    return g, labels


def clique_graph(
    num_nodes: int,
    num_cliques: int,
    seed: int = 0,
    max_short_circuit: int = 25,
):
    """k cliques of ~n/k nodes + 0..25 random cross edges per clique pair.

    Paper Sec. 5.4.  Returns (EdgeList, labels).
    """
    rng = np.random.default_rng(seed)
    sizes = np.full((num_cliques,), num_nodes // num_cliques, dtype=np.int64)
    sizes[: num_nodes % num_cliques] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)])
    edges = []
    labels = np.zeros((num_nodes,), dtype=np.int32)
    for k in range(num_cliques):
        lo, hi = int(starts[k]), int(starts[k + 1])
        labels[lo:hi] = k
        members = np.arange(lo, hi)
        iu = np.triu_indices(len(members), k=1)
        edges.append(np.stack([members[iu[0]], members[iu[1]]], axis=1))
    # short circuits between every pair of cliques
    seen = set()
    cross = []
    for a in range(num_cliques):
        for b in range(a + 1, num_cliques):
            m = int(rng.integers(0, max_short_circuit + 1))
            for _ in range(m):
                i = int(rng.integers(starts[a], starts[a + 1]))
                j = int(rng.integers(starts[b], starts[b + 1]))
                if (i, j) not in seen:
                    seen.add((i, j))
                    cross.append((i, j))
    if cross:
        edges.append(np.asarray(cross, dtype=np.int64))
    all_edges = np.concatenate(edges, axis=0).astype(np.int32)
    g = make_edge_list(all_edges, num_nodes)
    return g, labels


def sbm_graph(
    num_nodes: int,
    num_blocks: int,
    p_in: float = 0.5,
    p_out: float = 0.01,
    seed: int = 0,
):
    """Stochastic block model (Holland et al. 1983).  Returns (EdgeList, labels)."""
    rng = np.random.default_rng(seed)
    labels = np.sort(rng.integers(0, num_blocks, size=num_nodes)).astype(np.int32)
    iu = np.triu_indices(num_nodes, k=1)
    same = labels[iu[0]] == labels[iu[1]]
    p = np.where(same, p_in, p_out)
    mask = rng.random(len(p)) < p
    edges = np.stack([iu[0][mask], iu[1][mask]], axis=1).astype(np.int32)
    # ensure no isolated nodes (attach to a random same-block partner)
    present = np.zeros(num_nodes, bool)
    present[edges.ravel()] = True
    extra = []
    for v in np.nonzero(~present)[0]:
        u = (v + 1) % num_nodes
        extra.append((min(u, v), max(u, v)))
    if extra:
        edges = np.concatenate([edges, np.asarray(extra, np.int32)], axis=0)
    return make_edge_list(edges, num_nodes), labels


def sparse_sbm_graph(
    num_nodes: int,
    num_blocks: int,
    avg_degree_in: float = 8.0,
    avg_degree_out: float = 0.5,
    seed: int = 0,
):
    """Memory-light SBM for large n (>= 10k nodes, streaming benchmarks).

    `sbm_graph` materializes all O(n^2) node pairs; this samples a
    binomial edge COUNT per block pair and then draws endpoints, so cost
    is O(E).  Expected within-block degree is `avg_degree_in`, expected
    cross-block degree `avg_degree_out`.  Returns (EdgeList, labels).
    """
    rng = np.random.default_rng(seed)
    sizes = np.full((num_blocks,), num_nodes // num_blocks, dtype=np.int64)
    sizes[: num_nodes % num_blocks] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)])
    labels = np.repeat(np.arange(num_blocks), sizes).astype(np.int32)
    chunks = []
    for a in range(num_blocks):
        na = int(sizes[a])
        # within-block: n_a * deg_in / 2 edges in expectation
        pairs_in = na * (na - 1) // 2
        p_in = min(1.0, avg_degree_in / max(na - 1, 1))
        m = rng.binomial(pairs_in, p_in)
        if m:
            i = rng.integers(starts[a], starts[a + 1], size=m)
            j = rng.integers(starts[a], starts[a + 1], size=m)
            chunks.append(np.stack([i, j], axis=1))
        for b in range(a + 1, num_blocks):
            nb = int(sizes[b])
            p_out = min(1.0, avg_degree_out / max(num_nodes - na, 1))
            m = rng.binomial(na * nb, p_out)
            if m:
                i = rng.integers(starts[a], starts[a + 1], size=m)
                j = rng.integers(starts[b], starts[b + 1], size=m)
                chunks.append(np.stack([i, j], axis=1))
    edges = (np.concatenate(chunks, axis=0) if chunks
             else np.zeros((0, 2), np.int64))
    edges = edges[edges[:, 0] != edges[:, 1]]  # drop self loops
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    edges = np.unique(np.stack([lo, hi], axis=1), axis=0)
    # ensure no isolated nodes (chain to the next node in the same block;
    # a size-1 block chains to its global neighbour instead)
    present = np.zeros(num_nodes, bool)
    present[edges.ravel()] = True
    extra = []
    for v in np.nonzero(~present)[0]:
        blk = labels[v]
        if int(sizes[blk]) > 1:
            u = int(starts[blk]) + (v - int(starts[blk]) + 1) % int(sizes[blk])
        else:
            u = (v + 1) % num_nodes
        extra.append((min(u, v), max(u, v)))
    if extra:
        edges = np.concatenate([edges, np.asarray(extra, np.int64)], axis=0)
    return make_edge_list(edges.astype(np.int32), num_nodes), labels


def power_law_graph(
    num_nodes: int,
    avg_degree: float = 8.0,
    alpha: float = 2.5,
    seed: int = 0,
    dedup: bool = True,
):
    """Chung–Lu style power-law graph: endpoint probabilities follow a
    Pareto(alpha - 1) weight per node, so degrees are power-law with
    exponent ~alpha — the skewed-degree regime the chunked node-blocking
    layout exists for (hub blocks concentrate half-edges).

    Cost is O(E log n) (inverse-CDF endpoint draws), so it scales to the
    million-node / 5e7-edge acceptance row.  ``dedup=False`` skips the
    O(E) unique pass and keeps duplicate draws as parallel unit-weight
    edges (a weighted multigraph — every consumer in this repo sums
    parallel weights, so the spectrum just sees heavier hub edges);
    the default dedups for exact small-graph tests.  Self loops are
    dropped.  Returns an EdgeList (no planted labels — this family has
    none).
    """
    rng = np.random.default_rng(seed)
    w = rng.pareto(max(alpha - 1.0, 1e-3), size=num_nodes) + 1.0
    p = w / w.sum()
    m = max(int(num_nodes * avg_degree / 2), 1)
    src = rng.choice(num_nodes, size=m, p=p)
    dst = rng.choice(num_nodes, size=m, p=p)
    keep = src != dst
    lo = np.minimum(src[keep], dst[keep]).astype(np.int64)
    hi = np.maximum(src[keep], dst[keep]).astype(np.int64)
    edges = np.stack([lo, hi], axis=1)
    if dedup:
        edges = np.unique(edges, axis=0)
    if len(edges) == 0:  # degenerate tiny draw: keep the graph non-empty
        edges = np.asarray([[0, min(1, num_nodes - 1)]], np.int64)
    return make_edge_list(edges, num_nodes)


def ring_of_cliques(num_cliques: int, clique_size: int):
    """Deterministic well-clustered graph for exact tests."""
    n = num_cliques * clique_size
    edges = []
    labels = np.zeros((n,), dtype=np.int32)
    for k in range(num_cliques):
        lo = k * clique_size
        labels[lo: lo + clique_size] = k
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                edges.append((lo + i, lo + j))
        nxt = ((k + 1) % num_cliques) * clique_size
        edges.append((min(lo, nxt), max(lo, nxt)))
    return make_edge_list(np.asarray(edges, np.int32), n), labels
