"""jax version-compatibility shims.

The repo targets a range of jax releases.  ``shard_map`` in particular has
moved twice:

  * jax < ~0.6:  ``jax.experimental.shard_map.shard_map`` with a
    ``check_rep=`` kwarg;
  * newer jax:   top-level ``jax.shard_map`` with the kwarg renamed to
    ``check_vma=``.

``from repro.compat import shard_map`` works on both: it resolves the
import at module load and translates ``check_vma``/``check_rep`` to
whatever the installed jax accepts.
"""
from __future__ import annotations

import functools
import inspect

import jax

try:  # newer jax exposes shard_map at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax <= 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f=None, **kwargs):
    """Version-agnostic ``shard_map``; usable directly or via
    ``functools.partial(shard_map, mesh=..., ...)`` as a decorator."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        val = kwargs.pop("check_vma")
        if "check_rep" in _PARAMS:
            kwargs["check_rep"] = val
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        val = kwargs.pop("check_rep")
        if "check_vma" in _PARAMS:
            kwargs["check_vma"] = val
    if f is None:
        return functools.partial(shard_map, **kwargs)
    return _shard_map(f, **kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    ``jax.sharding.AxisType`` only exists on newer jax (explicit-sharding
    releases); older jax meshes are implicitly Auto, so the kwarg is
    simply dropped there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def default_edge_mesh(max_shards: int | None = None,
                      axis_names=("data", "model")):
    """The ("data", "model") edge-sharding mesh over all local devices.

    Every edge-parallel entry point in this repo (`core.distributed`,
    `stream.sharded`, the distributed test lane and benchmarks) shards
    edges over "data"; this helper builds that mesh from however many
    devices the process sees — 1 in plain tier-1 runs, 8 under the CI
    lane's ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — so
    call sites don't hand-roll device reshapes per jax version.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs) if max_shards is None else min(len(devs), max_shards)
    return Mesh(np.array(devs[:n]).reshape(n, 1), axis_names)


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    Newer jax: ``jax.set_mesh(mesh)``.  Older jax: the Mesh object is
    itself the context manager (``with mesh:``), tracked in thread
    resources — which is exactly where :func:`get_abstract_mesh` falls
    back to reading.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def get_abstract_mesh():
    """The mesh currently in context (``with mesh:`` / ``use_mesh``).

    ``jax.sharding.get_abstract_mesh`` only exists on newer jax; older
    releases track the context mesh in thread resources.  Both return an
    object with ``.empty``, ``.axis_names`` and ``.axis_sizes``.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as _mesh_lib

    return _mesh_lib.thread_resources.env.physical_mesh
