"""Streaming graph-clustering service on top of SPED.

The one-shot pipeline (edges → dilated reversed Laplacian → top-k solver
→ k-means) assumes a frozen graph; real graphs arrive as streams of edge
updates.  This subsystem turns the pipeline into a long-running,
multi-tenant service where re-clustering after an update costs a small
fraction of a cold solve: dilation keeps per-iteration contraction high,
warm starts keep iteration counts low, and first-order eigen-updates
skip the solver entirely for small perturbations.

Module map
----------
graph_store
    jit-stable mutable edge store: padded capacity classes (powers of
    two), fixed-size batched insert/delete/reweight upserts, lazy degree
    recomputation, EdgeList views consumable by every core operator.
warm
    Warm-started solver sessions: seed from the previous panel via
    solvers.init_from_panel, restart-vs-continue decided by the block
    residual of the old panel under the new operator, chunked
    run-to-tolerance loop (the reconvergence engine).
updates
    Dhanjal-style first-order incremental eigen-updates from realized
    edge-weight deltas, with an accumulated-drift bound that triggers
    automatic fallback to a full (warm-started) SPED re-solve.
service
    Multi-tenant session manager: admission into capacity classes with
    probe-driven DilationPlans (per-session lr/scale traced, per-class
    degree re-planned on the snapped planner grid), batched jitted
    ticks built by repro.core.program (one compiled program per
    (class, degree, layout, occupancy); the scheduler's per-session
    step multipliers ride as a traced input), the residual-decay
    tick scheduler, per-session convergence via panel residuals
    (converged sessions cost zero device work), eviction with panel
    caching (``add_graph(resume_panel=)`` re-admission), streaming
    updates routed through the incremental path, and label serving.
sharded
    Mesh-parallel serving policy (``ServiceConfig(mesh=...)``):
    shard-balanced capacities and the per-shard decomposition contract;
    the shard_mapped tick programs themselves live in
    ``repro.core.program`` (one psum of the stacked panels per dilation
    matvec, sharded admission probes).
tracking
    Stable cluster ids across re-solves: greedy maximum-overlap matching
    of each new k-means labelling onto the previous one.

Entry points: ``StreamingService`` for the service,
``benchmarks/bench_stream.py`` for updates/sec and
iterations-to-reconverge numbers, ``examples/streaming_clustering.py``
for an end-to-end walkthrough.
"""
from repro.stream.graph_store import (  # noqa: F401
    CAPACITY_CLASSES,
    BatchStats,
    EdgeBatch,
    GraphStore,
    apply_edge_batch,
    as_edge_list,
    capacity_class,
    coalesce_batch,
    from_edge_list,
    grow,
    make_edge_batch,
    num_edges,
    refresh_degrees,
)
from repro.stream.service import (  # noqa: F401
    ServiceConfig,
    StreamingService,
    UnknownSessionError,
    node_capacity_class,
)
from repro.stream.tracking import (  # noqa: F401
    LabelTracker,
    label_churn,
    match_labels,
)
from repro.stream.updates import (  # noqa: F401
    EigenEstimate,
    UpdateConfig,
    anchor_estimate_arrays,
    estimate_from_panel,
    first_order_update,
    should_fallback,
)
from repro.stream.warm import (  # noqa: F401
    WarmConfig,
    reconverge,
    run_to_tolerance,
    warm_start_state,
)
