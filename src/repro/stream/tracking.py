"""Stable cluster ids across re-solves.

k-means labels are only defined up to permutation, and every re-solve
(or even re-run of k-means) can permute them.  Downstream consumers of a
streaming clustering service need STABLE ids: cluster 3 today should be
cluster 3 after tonight's edge batch unless the community actually
changed.  `LabelTracker` matches each new labelling to the previous one
by greedy maximum-overlap assignment (the same greedy used by
kmeans.cluster_agreement, here returning the permutation instead of the
score) and relabels accordingly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def overlap_matrix(ref: jax.Array, new: jax.Array, k: int) -> jax.Array:
    """(k, k) counts: [i, j] = #nodes with ref label i and new label j."""
    m = jnp.zeros((k, k))
    return m.at[ref, new].add(1.0)


@jax.jit
def _greedy_perm(conf: jax.Array) -> jax.Array:
    """perm[j] = stable id for new label j, by repeatedly taking the
    largest remaining overlap cell (each pick eliminates one row+col, so
    after k picks the permutation is total and injective)."""
    k = conf.shape[0]

    def body(_, carry):
        conf, perm = carry
        idx = jnp.argmax(conf)
        i, j = idx // k, idx % k
        perm = perm.at[j].set(i)
        conf = conf.at[i, :].set(-1.0).at[:, j].set(-1.0)
        return conf, perm

    _, perm = jax.lax.fori_loop(
        0, k, body, (conf, jnp.zeros((k,), jnp.int32)))
    return perm


def match_labels(ref: jax.Array, new: jax.Array, k: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Permute `new`'s label ids to maximize (greedy) overlap with `ref`.

    Returns (relabelled, perm) with relabelled = perm[new].
    """
    perm = _greedy_perm(overlap_matrix(ref, new, k))
    return perm[new], perm


def label_churn(prev: np.ndarray, new: np.ndarray) -> float:
    """Fraction of nodes whose STABLE id changed between two servings.

    Both inputs must already be stable-id labellings of the SAME node
    set (successive `LabelTracker.update` outputs) — after the tracker
    has absorbed pure permutations, whatever churn remains is genuine
    community movement.  The serving layer's versioned results store
    (repro.serve.results) reports this per committed version as the
    client-visible stability metric backing the stable-ids guarantee.
    """
    prev = np.asarray(prev)
    new = np.asarray(new)
    if prev.shape != new.shape:
        raise ValueError(
            f"label shapes differ: {prev.shape} vs {new.shape}")
    if prev.size == 0:
        return 0.0
    return float(np.mean(prev != new))


class LabelTracker:
    """Per-session label continuity: feed each fresh labelling through
    `update`, read back stable ids.

    The streaming service keeps one tracker per session; the serving
    layer's versioned results store keeps its own per-session tracker
    fed in commit order, which is what turns "labels are stable up to
    relabelling" into a client-visible guarantee: cluster 3 today is
    cluster 3 after tonight's re-solve unless the community itself
    moved (measured by :func:`label_churn`).
    """

    def __init__(self, num_clusters: int):
        self.k = num_clusters
        self.ref: jax.Array | None = None

    def update(self, labels: jax.Array) -> jax.Array:
        labels = jnp.asarray(labels)
        if self.ref is None:
            self.ref = labels
            return labels
        stable, _ = match_labels(self.ref, labels, self.k)
        self.ref = stable
        return stable
