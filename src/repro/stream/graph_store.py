"""jit-stable mutable edge store with padded capacity classes.

The streaming service's ground truth for each graph.  Shapes never depend
on the live edge count: the edge buffer is padded to a CAPACITY CLASS
(power of two), and mutations are fixed-size batched upserts, so every
graph in a class shares one compiled program (store update, matvec,
solver tick).

Slot convention: ``weight == 0``  <=>  the slot is free/inert.  A free
slot contributes nothing to any edge-wise computation, which is exactly
the contract of :func:`repro.core.laplacian.pad_edge_list` — so
``as_edge_list(store)`` feeds every existing operator (dense L, matvec,
series, sharded matvec) unchanged.

Degrees are cached and recomputed LAZILY: mutations only set a dirty
flag; :func:`refresh_degrees` recomputes under ``lax.cond`` the next time
degrees are actually needed (spectral-radius bound, dilation scale).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.laplacian import EdgeList

# Edge-buffer capacity ladder (powers of two).  Few classes => few
# compiled programs; headroom on admission makes growth rare.  The top
# rungs (2^25, 2^26 ~ 67M edges) exist for the million-node tier: a
# streamed n=1M, E~50M power-law graph admits without overflowing.
CAPACITY_CLASSES = tuple(2 ** p for p in range(8, 27))


def capacity_class(num_edges: int, headroom: float = 1.5) -> int:
    """Smallest ladder capacity >= num_edges * headroom."""
    want = max(int(np.ceil(num_edges * headroom)), 1)
    for c in CAPACITY_CLASSES:
        if c >= want:
            return c
    raise ValueError(f"{num_edges} edges exceeds the capacity ladder")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GraphStore:
    """Fixed-capacity mutable graph; a pytree with static num_nodes."""

    src: jax.Array  # (cap,) int32, src < dst for live slots
    dst: jax.Array  # (cap,) int32
    weight: jax.Array  # (cap,) float32; 0 => slot free
    deg: jax.Array  # (num_nodes,) float32 cached weighted degrees
    deg_dirty: jax.Array  # () bool — True => deg is stale
    num_nodes: int  # static (may itself be a padded node capacity)

    @property
    def capacity(self) -> int:
        return self.src.shape[0]

    def tree_flatten(self):
        return (
            (self.src, self.dst, self.weight, self.deg, self.deg_dirty),
            self.num_nodes,
        )

    @classmethod
    def tree_unflatten(cls, num_nodes, children):
        return cls(*children, num_nodes=num_nodes)


class EdgeBatch(NamedTuple):
    """A fixed-size batch of edge mutations (canonicalized on build).

    Semantics per entry under mode="set": upsert the edge (src, dst) to
    `weight`; weight 0 deletes.  Under mode="add": add `weight` to the
    current weight (inserting if absent; reaching exactly 0 deletes).
    Entries must have UNIQUE canonical (src, dst) pairs — use
    :func:`coalesce_batch` for raw update streams.  Padding entries
    (src == dst == 0, weight == 0) are no-ops and must sit at the END of
    the batch so real inserts claim free slots first.
    """

    src: jax.Array  # (B,) int32
    dst: jax.Array  # (B,) int32
    weight: jax.Array  # (B,) float32


def make_edge_batch(edges, weights, pad_to: int | None = None) -> EdgeBatch:
    """Canonicalize + zero-pad an update batch to a fixed size.

    Self-loop entries (src == dst) are dropped: a self-loop contributes
    nothing to a Laplacian, and a live (0, 0) slot would collide with
    the padding sentinel (and double-count in cached degrees).
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    weights = np.asarray(weights, dtype=np.float32).reshape(-1)
    proper = edges[:, 0] != edges[:, 1]
    edges, weights = edges[proper], weights[proper]
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    b = len(weights)
    size = b if pad_to is None else pad_to
    if size < b:
        raise ValueError(f"pad_to {pad_to} < batch size {b}")
    src = np.zeros((size,), np.int32)
    dst = np.zeros((size,), np.int32)
    w = np.zeros((size,), np.float32)
    src[:b], dst[:b], w[:b] = lo, hi, weights
    return EdgeBatch(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w))


def coalesce_batch(edges, weights, mode: str = "set",
                   pad_to: int | None = None) -> EdgeBatch:
    """Collapse duplicate pairs in a raw update stream (host-side).

    mode="set": last write wins;  mode="add": deltas sum.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    weights = np.asarray(weights, dtype=np.float32).reshape(-1)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    out: dict[tuple[int, int], float] = {}
    for s, d, w in zip(lo, hi, weights):
        if s == d:
            continue  # self-loops are no-ops on a Laplacian
        key = (int(s), int(d))
        if mode == "add":
            out[key] = out.get(key, 0.0) + float(w)
        else:
            out[key] = float(w)
    pairs = np.asarray(list(out.keys()), np.int64).reshape(-1, 2)
    vals = np.asarray(list(out.values()), np.float32)
    return make_edge_batch(pairs, vals, pad_to=pad_to)


def from_edge_list(g: EdgeList, capacity: int | None = None,
                   num_nodes: int | None = None) -> GraphStore:
    """Admit a graph: pad its edges into a capacity-class buffer.

    `num_nodes` may exceed g.num_nodes to place the graph in a padded
    NODE capacity class (extra nodes are isolated and inert as long as
    eigen-panels keep zero rows there — see stream.service).
    """
    n = g.num_nodes if num_nodes is None else num_nodes
    if n < g.num_nodes:
        raise ValueError("num_nodes below the graph's node count")
    cap = capacity_class(g.num_edges) if capacity is None else capacity
    if cap < g.num_edges:
        raise ValueError(f"capacity {cap} < num_edges {g.num_edges}")
    pad = cap - g.num_edges
    src = jnp.concatenate([g.src, jnp.zeros((pad,), jnp.int32)])
    dst = jnp.concatenate([g.dst, jnp.zeros((pad,), jnp.int32)])
    w = jnp.concatenate([g.weight, jnp.zeros((pad,), jnp.float32)])
    deg = jnp.zeros((n,), jnp.float32).at[src].add(w).at[dst].add(w)
    return GraphStore(src=src, dst=dst, weight=w, deg=deg,
                      deg_dirty=jnp.zeros((), bool), num_nodes=n)


def as_edge_list(store: GraphStore) -> EdgeList:
    """Zero-copy padded EdgeList view; free slots are inert."""
    return EdgeList(src=store.src, dst=store.dst, weight=store.weight,
                    num_nodes=store.num_nodes)


def num_edges(store: GraphStore) -> jax.Array:
    """Live edge count (traced scalar)."""
    return jnp.sum(store.weight != 0.0)


def grow(store: GraphStore, capacity: int | None = None) -> GraphStore:
    """Host-side move to the next capacity class (recompiles downstream)."""
    old = store.capacity
    if capacity is None:
        bigger = [c for c in CAPACITY_CLASSES if c > old]
        if not bigger:
            raise ValueError("already at the top capacity class")
        capacity = bigger[0]
    pad = capacity - old
    if pad < 0:
        raise ValueError(f"cannot shrink {old} -> {capacity}")
    return dataclasses.replace(
        store,
        src=jnp.concatenate([store.src, jnp.zeros((pad,), jnp.int32)]),
        dst=jnp.concatenate([store.dst, jnp.zeros((pad,), jnp.int32)]),
        weight=jnp.concatenate([store.weight, jnp.zeros((pad,), jnp.float32)]),
    )


class BatchStats(NamedTuple):
    matched: jax.Array  # () int32 — entries that updated an existing edge
    inserted: jax.Array  # () int32 — entries that claimed a free slot
    dropped: jax.Array  # () int32 — inserts lost to a full buffer


@jax.jit
def _apply_set(store: GraphStore, batch: EdgeBatch):
    return _apply(store, batch, False)


@jax.jit
def _apply_add(store: GraphStore, batch: EdgeBatch):
    return _apply(store, batch, True)


def _apply(store: GraphStore, batch: EdgeBatch, add: bool):
    cap = store.capacity
    b = batch.src.shape[0]
    occ = store.weight != 0.0
    # (B, cap) match of live slots; O(B * cap) compare — branch-free and
    # batched, the jit-stable trade the store makes for hash tables.
    match = (
        (store.src[None, :] == batch.src[:, None])
        & (store.dst[None, :] == batch.dst[:, None])
        & occ[None, :]
    )
    found = jnp.any(match, axis=1)
    match_idx = jnp.argmax(match, axis=1)
    # No-op entries (padding, or deletes of absent edges) write nothing:
    # they must neither consume a free slot nor count as drops, or a
    # padded reweight batch near capacity would spuriously overflow.
    noop = (batch.weight == 0.0) & ~found
    needs_slot = ~found & ~noop
    # i-th entry needing a slot gets the i-th free slot; fill=cap when the
    # buffer runs out, and the scatter below then drops that write.
    free_idx = jnp.nonzero(~occ, size=b, fill_value=cap)[0]
    new_rank = jnp.cumsum(needs_slot) - 1
    slot = jnp.where(
        found, match_idx,
        jnp.where(needs_slot, free_idx[jnp.clip(new_rank, 0, b - 1)], cap))
    in_range = slot < cap
    old_w = jnp.where(found, store.weight[jnp.clip(slot, 0, cap - 1)], 0.0)
    new_w = old_w + batch.weight if add else batch.weight
    applied_w = jnp.where(in_range, new_w, 0.0)
    dw = applied_w - jnp.where(in_range, old_w, 0.0)  # realized weight deltas
    src = store.src.at[slot].set(batch.src, mode="drop")
    dst = store.dst.at[slot].set(batch.dst, mode="drop")
    weight = store.weight.at[slot].set(new_w, mode="drop")
    stats = BatchStats(
        matched=jnp.sum(found.astype(jnp.int32)),
        inserted=jnp.sum((needs_slot & in_range).astype(jnp.int32)),
        dropped=jnp.sum((needs_slot & ~in_range).astype(jnp.int32)),
    )
    new_store = dataclasses.replace(
        store, src=src, dst=dst, weight=weight,
        deg_dirty=jnp.ones((), bool))
    return new_store, dw, stats


def apply_edge_batch(store: GraphStore, batch: EdgeBatch, mode: str = "set"):
    """Apply a batched upsert; returns (store', dw, stats).

    `dw` is the REALIZED per-entry weight delta (0 for dropped/no-op
    entries) — exactly the ΔL description the incremental eigen-update
    path consumes (stream.updates).  Jitted once per (capacity, batch
    size, mode).
    """
    if mode == "set":
        return _apply_set(store, batch)
    if mode == "add":
        return _apply_add(store, batch)
    raise ValueError(f"unknown mode {mode!r}")


@jax.jit
def refresh_degrees(store: GraphStore) -> GraphStore:
    """Lazy degree recomputation: only pays the O(capacity) scatter when
    the cache is actually stale."""

    def recompute(s):
        return (
            jnp.zeros_like(s.deg).at[s.src].add(s.weight).at[s.dst].add(s.weight)
        )

    deg = jax.lax.cond(store.deg_dirty, recompute, lambda s: s.deg, store)
    return dataclasses.replace(store, deg=deg, deg_dirty=jnp.zeros((), bool))


def spectral_radius_upper_bound(store: GraphStore) -> tuple[GraphStore, jax.Array]:
    """(refreshed store, 2 * max weighted degree) — the Sec. 5.4 bound."""
    store = refresh_degrees(store)
    return store, 2.0 * jnp.max(store.deg)


def node_blocking(store: GraphStore, *, block_n: int = 512,
                  block_e: int = 128):
    """Host-side node-blocked half-edge layout of the store's LIVE edges
    for the pallas matvec backend (repro.core.backend).

    Built once per admission / re-solve and cached alongside the padded
    buffers by the owner (the streaming service keeps it per session);
    edge mutations invalidate it — rebuild after ``apply_edge_batch``.
    Free slots are dropped during bucketing (they are inert and would
    otherwise pile into node-block 0), so the layout's chunk count
    tracks the LIVE edge count, snapped to powers of two: sessions of
    one capacity class with similar skew share one compiled program.
    """
    from repro.core import backend as backend_mod

    return backend_mod.build_node_blocking(
        np.asarray(store.src), np.asarray(store.dst),
        np.asarray(store.weight), store.num_nodes,
        block_n=min(block_n, store.num_nodes), block_e=block_e)


def sharded_node_blocking(store: GraphStore, num_shards: int,
                          *, block_n: int = 512, block_e: int = 128):
    """Per-shard node-blocked layouts of the store's edge buffer for the
    mesh-parallel pallas tick (stream.sharded) — the sharded sibling of
    :func:`node_blocking`, cached alongside it by the owner and
    invalidated the same way (edge mutations stale it).

    The buffer's capacity must divide evenly into ``num_shards`` — the
    balance invariant admission/growth maintain via
    ``stream.sharded.balanced_capacity``.  Each shard's contiguous slice
    is bucketed independently with ONE shared pow2-snapped chunk count,
    so all shards (and all similar-skew sessions of a capacity class)
    compile against the same shapes; an all-padding slice yields an
    all-zero layout contributing exact zeros to the psum.
    """
    from repro.core import backend as backend_mod

    return backend_mod.build_sharded_node_blocking(
        np.asarray(store.src), np.asarray(store.dst),
        np.asarray(store.weight), store.num_nodes, num_shards,
        block_n=min(block_n, store.num_nodes), block_e=block_e)


def model_sharded_blocking(store: GraphStore, num_shards: int,
                           *, block_n: int = 512, block_e: int = 128):
    """Destination-aligned per-shard layouts of the store's live edges
    for the PANEL-sharded tick (``core.program.build_tick_model_sharded``)
    — shard ``s`` owns a contiguous row range of the eigenvector panel
    and every half-edge destined there.  Cached/invalidated exactly like
    :func:`node_blocking`.  No edge-buffer balance contract: any
    capacity works (skew moves live chunks between shards, not shapes),
    which is what makes this the layout of choice for million-node
    single-tenant sessions where the PANEL, not the edge buffer, is the
    scaling ceiling.
    """
    from repro.core import backend as backend_mod

    return backend_mod.build_model_sharded_blocking(
        np.asarray(store.src), np.asarray(store.dst),
        np.asarray(store.weight), store.num_nodes, num_shards,
        block_n=min(block_n, store.num_nodes), block_e=block_e)
