"""Warm-started SPED solver sessions (Zhuzhunashvili & Knyazev-style).

On a streaming graph, consecutive solves differ by a small edge
perturbation, so the previous eigenvector panel V is an excellent initial
guess — UNLESS the graph changed so much that iterating from V is slower
than restarting (the preconditioned-streaming observation).  The
restart-vs-continue decision here is the ground-truth-free block residual
of the OLD panel under the NEW operator:

    r = ||A V - V (V^T A V)||_F / ||A V||_F     (metrics.panel_residual)

r small  -> continue from QR(V)  (solvers.init_from_panel);
r large  -> the panel carries no usable information; restart cold.

Dilation composes multiplicatively with warm-starting: the dilated gaps
set the per-iteration contraction rate, the warm start sets the initial
error — both shrink iterations-to-reconverge.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import metrics, program, solvers

MatVec = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class WarmConfig:
    # residual above which the previous panel is considered uninformative
    # (a random orthonormal panel sits near sqrt(1 - k/n) ~ 1)
    restart_residual: float = 0.6
    tol: float = 1e-3  # reconvergence target on panel_residual
    chunk: int = 10  # solver steps between residual checks
    max_steps: int = 5000
    lr: float = 0.1
    method: str = "mu_eg"


def warm_start_state(
    key: jax.Array,
    op: MatVec,
    n: int,
    k: int,
    v_prev: jax.Array | None,
    restart_residual: float = 0.6,
) -> tuple[solvers.SolverState, dict]:
    """Seed a solver session: previous panel if it passes the restart
    test, random otherwise.  Returns (state, info)."""
    cold = solvers.init_state(key, n, k)
    if v_prev is None:
        return cold, {"warm": False, "residual": None}
    state = solvers.init_from_panel(v_prev)
    res = float(metrics.panel_residual(state.v, op(state.v)))
    if res <= restart_residual:
        return state, {"warm": True, "residual": res}
    return cold, {"warm": False, "residual": res}


def _chunk_runner(op: MatVec, method: str, chunk: int, lr: float):
    """Compiled chunk step, cached ON the operator object itself so
    repeated run_to_tolerance calls against the same operator — the
    streaming reconvergence pattern — retrace nothing, while the cache
    (which pins the op's captured edge buffers and the XLA executable)
    dies with the operator.  The op <-> runner reference cycle is
    ordinary gc fodder; no module-global cache pins process memory.
    Callables that reject attributes simply pay a retrace per call.
    """
    key = (method, chunk, lr)
    cache = getattr(op, "_warm_chunk_cache", None)
    if cache is not None and key in cache:
        return cache[key]
    step_fn = solvers.STEP_FNS[method]

    @jax.jit
    def run(st: solvers.SolverState):
        # the unified solve loop (core.program) — one chunk + residual
        return program.run_chunk(op, step_fn, st, lr, chunk)

    try:
        if cache is None:
            cache = {}
            op._warm_chunk_cache = cache
        cache[key] = run
    except AttributeError:
        pass
    return run


def run_to_tolerance(
    op: MatVec,
    state: solvers.SolverState,
    cfg: WarmConfig,
) -> tuple[solvers.SolverState, int, float]:
    """Iterate until panel_residual <= cfg.tol; returns
    (state, iterations_used, final_residual).

    The chunked loop is jitted once per (operator, hyperparameters) —
    see _chunk_runner; the host only sees one residual scalar every
    `chunk` steps — the convergence probe the streaming service's tick
    loop uses per session.
    """
    chunk = _chunk_runner(op, cfg.method, cfg.chunk, cfg.lr)
    used = 0
    res = float(metrics.panel_residual(state.v, op(state.v)))
    while res > cfg.tol and used < cfg.max_steps:
        state, r = chunk(state)
        used += cfg.chunk
        res = float(r)
    return state, used, res


def reconverge(
    key: jax.Array,
    op: MatVec,
    n: int,
    k: int,
    cfg: WarmConfig,
    v_prev: jax.Array | None = None,
) -> tuple[solvers.SolverState, dict]:
    """Full warm (or cold, if v_prev fails the restart test) re-solve.

    Returns (state, info) with info["iterations"] — the quantity the
    streaming benchmark compares against a cold solve.
    """
    state, info = warm_start_state(
        key, op, n, k, v_prev, cfg.restart_residual)
    state, used, res = run_to_tolerance(op, state, cfg)
    info = dict(info, iterations=used, residual=res)
    return state, info
