"""First-order incremental eigen-updates with drift-triggered fallback.

Dhanjal et al. ("Efficient Eigen-updating for Spectral Graph Clustering")
update the eigenbasis of a streaming graph far cheaper than re-solving.
This module implements the first-order (Rayleigh-Schrodinger) flavour for
the Laplacian: an edge batch with realized weight deltas {dw_e} is the
perturbation  ΔL = Σ_e dw_e x_e x_e^T  (rank <= B), and for eigenpairs
(λ_i, v_i) of L:

    λ_i' ≈ λ_i + v_i^T ΔL v_i
    v_i' ≈ v_i + Σ_{j≠i} (v_j^T ΔL v_i) / (λ_i - λ_j) · v_j

computed entirely from B-edge matvecs — O(B k + n k^2), no solver
iterations.  First-order accuracy degrades as accumulated perturbation
approaches the panel's eigengaps, so the module tracks a Frobenius drift
bound  Σ batches Σ_e 2|dw_e|  >= accumulated ||ΔL||_F and triggers
a FALLBACK to a full (warm-started, dilated) SPED re-solve when drift
exceeds `fallback_ratio` × (min panel eigengap) — the scheme's safety
valve.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.laplacian import edge_matvec_arrays

MatVec = Callable[[jax.Array], jax.Array]


class EigenEstimate(NamedTuple):
    """Tracked bottom-k eigenpairs of L plus accumulated perturbation."""

    lam: jax.Array  # (k,) eigenvalue estimates, ascending-ish
    v: jax.Array  # (n, k) orthonormal panel
    drift: jax.Array  # () accumulated upper bound on ||ΔL||_F since solve


@dataclasses.dataclass(frozen=True)
class UpdateConfig:
    # fallback when drift > fallback_ratio * min eigengap of the panel
    fallback_ratio: float = 0.5
    gap_floor: float = 1e-8  # denominators |λ_i - λ_j| below this are skipped


def estimate_from_panel(matvec: MatVec, v: jax.Array) -> EigenEstimate:
    """Anchor an estimate at a freshly solved panel: λ = diag(VᵀLV)."""
    lam = jnp.diagonal(v.T @ matvec(v))
    return EigenEstimate(lam=lam, v=v, drift=jnp.zeros((), v.dtype))


@jax.jit
def anchor_estimate_arrays(src: jax.Array, dst: jax.Array, w: jax.Array,
                           v: jax.Array) -> EigenEstimate:
    """Anchor an estimate on a padded edge buffer: ``lambda = diag(V^T L
    V)`` with drift reset (was ``stream.service._anchor_estimate``)."""
    return estimate_from_panel(
        lambda x: edge_matvec_arrays(src, dst, w, x), v)


def delta_matvec(src: jax.Array, dst: jax.Array, dw: jax.Array,
                 v: jax.Array) -> jax.Array:
    """ΔL @ v for an edge batch with realized weight deltas dw, O(B k)."""
    return edge_matvec_arrays(src, dst, dw, v)


def delta_norm_bound(dw: jax.Array) -> jax.Array:
    """||ΔL||_F <= Σ_e 2|dw_e|  (triangle inequality over per-edge
    contributions; each dw_e x_e x_eᵀ has Frobenius norm exactly 2|dw_e|).

    A per-edge sum, not 2·sqrt(Σdw²): edges sharing an endpoint stack
    their diagonal contributions, so the root-sum-of-squares form is NOT
    an upper bound for hub-centered batches.
    """
    return 2.0 * jnp.sum(jnp.abs(dw))


def min_gap(lam: jax.Array, floor: float = 1e-8) -> jax.Array:
    """Smallest consecutive gap of the sorted eigenvalue estimates."""
    s = jnp.sort(lam)
    return jnp.maximum(jnp.min(s[1:] - s[:-1]), floor)


@functools.partial(jax.jit, static_argnames=("gap_floor",))
def first_order_update(
    est: EigenEstimate,
    src: jax.Array,
    dst: jax.Array,
    dw: jax.Array,
    gap_floor: float = 1e-8,
) -> EigenEstimate:
    """One Dhanjal-style first-order eigen-update for an edge batch.

    Correction terms between eigenpairs closer than `gap_floor` are
    skipped (their 1/gap amplification is noise-dominated).
    """
    dv = delta_matvec(src, dst, dw, est.v)  # ΔL V, (n, k)
    c = est.v.T @ dv  # (k, k): c[j, i] = v_jᵀ ΔL v_i
    lam_new = est.lam + jnp.diagonal(c)
    k = est.lam.shape[0]
    denom = est.lam[None, :] - est.lam[:, None]  # [j, i] = λ_i - λ_j
    offdiag = ~jnp.eye(k, dtype=bool)
    safe = offdiag & (jnp.abs(denom) > gap_floor)
    coef = jnp.where(safe, c / jnp.where(safe, denom, 1.0), 0.0)
    v_new = est.v + est.v @ coef  # column i += Σ_j coef[j, i] v_j
    q, r = jnp.linalg.qr(v_new)  # restore orthonormality
    sign = jnp.sign(jnp.diagonal(r))
    sign = jnp.where(sign == 0, 1.0, sign)
    return EigenEstimate(
        lam=lam_new,
        v=q * sign[None, :],
        drift=est.drift + delta_norm_bound(dw),
    )


def should_fallback(est: EigenEstimate, cfg: UpdateConfig = UpdateConfig()
                    ) -> jax.Array:
    """True when accumulated perturbation endangers first-order validity."""
    return est.drift > cfg.fallback_ratio * min_gap(est.lam, cfg.gap_floor)


def update_or_flag(
    est: EigenEstimate,
    src: jax.Array,
    dst: jax.Array,
    dw: jax.Array,
    cfg: UpdateConfig = UpdateConfig(),
) -> tuple[EigenEstimate, bool]:
    """Apply the first-order update; report whether the caller must now
    fall back to a full re-solve (stream.service resets drift to 0 by
    re-anchoring via `estimate_from_panel` after that solve)."""
    est = first_order_update(est, src, dst, dw, gap_floor=cfg.gap_floor)
    return est, bool(should_fallback(est, cfg))
