"""Multi-tenant streaming clustering service.

Owns many mutable graphs (stream.graph_store), each with a live
eigenvector panel, and advances them with BATCHED jitted ticks built by
:mod:`repro.core.program` — the ONE solve loop shared with the one-shot
solver, the warm reconvergence path, and the distributed solves:

  * Sessions are grouped by CAPACITY CLASS — (node_cap, edge_cap) — plus
    their scheduled dilation DEGREE (and, on pallas, the node-blocking
    layout), and every group tick is ONE compiled `SolveProgram`
    invocation over the group's stacked edge buffers and panels.  Shapes
    never depend on a session's live edge count or real node count, so
    admitting graph #9 to a class that already ticked reuses the
    compiled step (no per-session recompilation).  Groups are padded to
    power-of-two occupancy of their ACTIVE (unconverged) members, so the
    compiled-program set stays logarithmic while converged sessions cost
    ZERO device work per tick.
  * The per-session operator is the dilated reversed Laplacian
    (I - c L)^degree — the paper's limit_neg_exp series with λ* = 0 —
    scheduled from a real :class:`~repro.spectral.plan.DilationPlan`:
    admission/re-solve probes (SLQ lambda_max + bottom-edge gap) feed
    ``plan_dilation``, which picks the per-session strength tau (capped
    by the wanted-decay guard and the configured ceiling), the
    per-CLASS degree (snapped onto the planner grid, re-planned on
    admission drift — a new tenant needing more dilation raises the
    class degree), and the per-session lr (``plan.suggested_lr``,
    normalized to the unit-scale program form).  The dilation scale c
    and lr are TRACED per-session inputs — different graphs, one
    program.  Wide-gap tenants get identity plans: degree-1 groups that
    spend ONE matvec per operator application.
  * Per-session convergence is the ground-truth-free panel residual;
    converged sessions leave the tick rotation entirely (their groups
    shrink — zero device work), get their eigen estimate anchored
    (stream.updates), and serve labels until edge updates arrive.
    Updates take the cheap first-order eigen-update path and only
    re-enter the solve rotation when accumulated drift triggers the
    fallback, warm-started per stream.warm's restart test.
  * The RESIDUAL-DECAY TICK SCHEDULER (``tick_schedule=
    "residual_decay"``, the default): each session's measured residual
    decay rate forecasts its remaining solver steps
    (core.program.predicted_steps_to_tol).  A session predicted to stay
    far above tolerance after an ordinary tick skips the intermediate
    residual evaluations by riding a MULTIPLIED tick — the multipliers
    are TRACED per-session chunk budgets inside the compiled program
    (members past their own budget freeze under a mask while slower
    peers keep stepping), so scheduling adds ZERO compiles — fewer
    program invocations, fewer eval operator applications, and fewer
    host round-trips to fleet convergence, with identical solver math.
    Because a frozen slot still executes device steps, a group mixing
    plain and stretched members sub-batches into two invocations of
    the same compiled programs when that costs fewer slot-steps
    (``_split_by_multiplier``).  ``tick_schedule="round_robin"``
    restores fixed-size ticks.

Node padding invariant: panels keep EXACT zeros on rows >= the session's
real node count.  No edge ever touches a padding node, and every solver
operation (edge matvec, series recurrence, QR, normalization) maps zero
rows to zero rows, so the padded problem is numerically identical to the
unpadded one.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import kmeans as km
from repro.core import operators, program, solvers
from repro.kernels.edge_spmm import ops as es_ops
from repro.spectral import plan as plan_mod
from repro.spectral import probes as spectral_probes
from repro.stream import graph_store as gs
from repro.stream import tracking, updates


_next_pow2 = es_ops.next_pow2

# Families the tick programs can execute: the (I - c L)^degree form only
# (identity rides as degree 1 with c = 1/lambda*); cheb recurrences need
# the series evaluator, so the planner weakens tau into the budget
# instead of switching family.
_TICK_FAMILIES = ("identity", "limit_neg_exp")


def node_capacity_class(num_nodes: int) -> int:
    """Node-count capacity class (power of two >= num_nodes)."""
    return max(_next_pow2(num_nodes), 64)


def _split_by_multiplier(members: list, mults: np.ndarray) -> list:
    """Sub-batch a tick group so short-budget members don't ride a
    long invocation.  The shared program's device cost is occupancy x
    the LARGEST member budget — short-budget members freeze under the
    per-session chunk mask (``core.program``) but their slots still
    step — so batching a plain (multiplier-1) member with a stretched
    one executes the stretched member's whole budget in the plain
    member's slot for nothing.  Members bucket by pow2 of their
    multiplier (within-bucket waste stays under 2x), then adjacent
    buckets greedily re-merge whenever pow2 occupancy padding makes
    the joint invocation no dearer in slot-steps (e.g. 1 plain + 7
    stretched pads to occupancy 8 either way).  Sub-batches reuse the
    same compiled programs at smaller occupancy buckets; singleton and
    uniform-multiplier groups never split."""
    buckets: dict[int, list[int]] = {}
    for i, m in enumerate(mults):
        buckets.setdefault((int(m) - 1).bit_length(), []).append(i)
    if len(buckets) == 1:
        return [(members, mults)]
    subs = [idx for _, idx in sorted(buckets.items())]
    merged = [subs[0]]
    for idx in subs[1:]:
        prev = merged[-1]
        cost_split = (_next_pow2(len(prev)) * int(mults[prev].max())
                      + _next_pow2(len(idx)) * int(mults[idx].max()))
        cost_joint = (_next_pow2(len(prev) + len(idx))
                      * int(mults[idx].max()))
        if cost_joint <= cost_split:
            merged[-1] = prev + idx
        else:
            merged.append(idx)
    return [([members[i] for i in s], mults[s]) for s in merged]


class UnknownSessionError(KeyError):
    """An operation referenced a session id that was never admitted or
    was already evicted.

    Subclasses ``KeyError`` for backward compatibility with callers that
    guarded the old raw-dict lookups; the serving layer
    (:mod:`repro.serve`) relies on the typed class to map these to 404
    responses instead of a generic 500.
    """

    def __init__(self, sid: str):
        super().__init__(sid)
        self.sid = sid

    def __str__(self) -> str:  # KeyError.__str__ repr-quotes the arg
        return f"unknown or evicted session {self.sid!r}"


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    k: int = 6  # eigenvectors tracked per session
    num_clusters: int = 4  # default clusters served per session
    method: str = "mu_eg"  # solver step: "mu_eg" | "oja"
    lr: float = 0.3  # base step size (per-session values trace over it)
    degree: int = 15  # odd; BUDGET for the planned per-class degree
    dilation_strength: float = 8.0  # ceiling on the planned tau
    steps_per_tick: int = 20  # solver steps per session per tick
    tol: float = 2e-3  # panel-residual convergence target
    restart_residual: float = 0.6  # warm.py restart test
    fallback_ratio: float = 0.5  # updates.py drift fallback
    min_batch_pad: int = 16  # update batches pad to pow2 >= this
    drop_trivial: bool = True  # skip the all-ones nullvector in embeddings
    kmeans_restarts: int = 8
    seed: int = 0
    # SLQ spectral probing (repro.spectral): a tight lambda_max estimate
    # replaces the Gershgorin 2*max_degree bound when setting the
    # dilation scale — the bound over-estimates by ~2x on dense graphs,
    # silently halving the dilation.  Probes run on session admission
    # and on drift-triggered re-solves; ordinary update batches keep the
    # cheap bound-only rescale.  The bound always survives as cap (it is
    # certain; the probe is not) and as fallback when probing is off.
    probe_spectrum: bool = True
    probe_vectors: int = 2  # SLQ probe vectors per (re-)probe
    probe_steps: int = 16  # Lanczos steps per probe vector
    # Matvec backend for tick programs and probes (repro.core.backend):
    # "auto" = pallas on TPU, segment elsewhere.  Pallas ticks run the
    # node-blocked incidence-SpMM kernel with the dilation step fused
    # into its epilogue; the per-session blocking is built on admission
    # and rebuilt after edge updates (graph_store.node_blocking), and
    # sessions group by (capacity class, degree, blocking layout) — the
    # chunk count is pow2-snapped, so compile counts stay logarithmic.
    backend: str = "auto"
    tick_block_n: int = 512  # node-block rows per VMEM panel slice
    # Device mesh for SHARDED serving (core.program sharded builders):
    # when set, every group tick runs as one shard_mapped fused series
    # program with the group's edge buffers (segment) or per-shard node
    # blockings (pallas) partitioned over `edge_axes`, one psum of the
    # stacked panels per dilation matvec, and admission probes routed
    # through the same sharded matvec.  Admission/growth round edge
    # capacities up to a multiple of the shard count so shard slices
    # stay balanced.  None = single-device ticks (the default).
    mesh: object | None = None
    edge_axes: tuple = ("data",)
    # PANEL sharding (core.program.build_tick_model_sharded): when set
    # (with a mesh), every group tick shards the (n, k) panel itself
    # over these mesh axes — shard s owns rows [s*R, (s+1)*R) and the
    # destination-aligned half-edges landing there
    # (graph_store.model_sharded_blocking) — and mu-EG solver steps ship
    # their row assembly and 2k x 2k gram in ONE fused collective.  This
    # is the million-node serving mode: no device ever materializes
    # per-shard panel copies of the edge buffer, and admission probes
    # route through the same row-sharded matvec.  None = replicated
    # panels (edge sharding over `edge_axes` if a mesh is set).
    model_axes: tuple | None = None
    # Residual-decay tick scheduling: "residual_decay" forecasts each
    # SESSION's remaining solver steps from its measured residual decay
    # and gives it its own chunk budget (a TRACED per-session count —
    # any mix reuses the group's one compiled program) when it is
    # predicted to stay above `eval_payoff * steps_per_tick` steps from
    # tolerance — the intermediate residual evals would have no payoff.
    # Members past their budget freeze inside the shared program, so a
    # soon-converging member no longer caps its group's cadence.
    # "round_robin" = fixed-size ticks for every group.
    tick_schedule: str = "residual_decay"
    max_tick_multiplier: int = 8  # cap on the scheduled multiplier
    eval_payoff: float = 2.0  # multiply only past this many plain ticks
    # Sessions within this factor of tol cap their multiplier at a
    # gentle 2: the measured decay rate plateaus against the residual
    # floor near convergence (rate -> 1), so forecasts there are
    # unreliable in both directions — a full-forecast stretch executes
    # hundreds of steps for a session one short hop from tolerance,
    # while plain ticks grind out an invocation per hop.
    stretch_residual_floor: float = 4.0

    def __post_init__(self):
        if self.degree % 2 == 0:
            raise ValueError("degree must be odd (limit_neg_exp series)")
        if self.backend not in backend_mod.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.tick_schedule not in ("round_robin", "residual_decay"):
            raise ValueError(
                f"unknown tick_schedule {self.tick_schedule!r}")
        if self.mesh is not None:
            axes = tuple(self.edge_axes) + tuple(self.model_axes or ())
            missing = [a for a in axes if a not in self.mesh.axis_names]
            if missing:
                raise ValueError(
                    f"mesh axes {missing} not in mesh axes "
                    f"{self.mesh.axis_names}")
        elif self.model_axes is not None:
            raise ValueError("model_axes requires a mesh")


@dataclasses.dataclass
class _Session:
    sid: str
    n: int  # real node count (<= store.num_nodes == node capacity)
    num_clusters: int
    store: gs.GraphStore
    v: jax.Array  # (node_cap, k) panel, zero rows >= n
    plan: plan_mod.DilationPlan  # the session's dilation schedule source
    rho_ub: float  # Gershgorin bound at the time plan.rho was set
    lr: float  # per-session step size (traced into the tick program)
    plan_degree: int  # the session's own planned degree suggestion
    tracker: tracking.LabelTracker
    blocking: es_ops.NodeBlocking | None = None  # pallas tick layout cache
    # per-shard layout cache for sharded pallas ticks; invalidated
    # together with `blocking` on edge mutations
    sharded_blocking: es_ops.ShardedNodeBlocking | None = None
    # destination-aligned layout cache for PANEL-sharded ticks
    # (ServiceConfig.model_axes); same invalidation discipline
    model_blocking: es_ops.ModelShardedBlocking | None = None
    group_key: tuple | None = None  # last tick-group key (introspection)
    est: updates.EigenEstimate | None = None
    converged: bool = False
    residual: float = float("inf")
    rate: float | None = None  # measured per-step residual decay ratio
    ticks: int = 0
    solves: int = 0  # full (re-)solve episodes entered
    incremental_updates: int = 0
    fallbacks: int = 0

    @property
    def rho(self) -> float:
        return self.plan.rho

    @property
    def tau(self) -> float:
        return self.plan.tau


def panel_labels(panel, num_clusters: int, *, drop_trivial: bool = True,
                 seed: int = 0, kmeans_restarts: int = 8) -> np.ndarray:
    """Raw k-means labelling of an (n, k) embedding panel — the
    tracker-free labelling primitive shared by :meth:`StreamingService
    .labels` (which feeds it through the session tracker) and the serve
    layer's versioned results store (which runs its own tracker in
    commit order so served ids stay stable)."""
    panel = jnp.asarray(panel)
    start = 1 if drop_trivial else 0
    emb = panel[:, start: start + num_clusters]
    norms = jnp.linalg.norm(emb, axis=1, keepdims=True)
    emb = emb / jnp.maximum(norms, 1e-12)
    res = km.kmeans(
        jax.random.PRNGKey(seed + 2), emb, num_clusters,
        restarts=kmeans_restarts)
    return np.asarray(res.labels)


@functools.partial(jax.jit, static_argnames=("node_cap", "n", "k"))
def _init_panel(key, node_cap: int, n: int, k: int):
    """Random orthonormal panel supported on the first n rows."""
    v = jax.random.normal(key, (node_cap, k), jnp.float32)
    v = v * (jnp.arange(node_cap) < n)[:, None]
    q, _ = jnp.linalg.qr(v)
    return q


class StreamingService:
    """Session manager: admission, streaming updates, batched ticking,
    label serving, eviction."""

    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        from repro.stream import sharded as sharded_mod

        self.cfg = cfg
        self._backend = backend_mod.resolve_backend(cfg.backend)
        self._mesh = cfg.mesh
        # panel sharding is orthogonal to edge sharding: model serving
        # re-buckets half-edges by destination shard itself, so the
        # edge-balance contract (and _num_shards) stays on edge_axes
        self._model_axes = (tuple(cfg.model_axes)
                            if cfg.mesh is not None
                            and cfg.model_axes is not None else None)
        self._model_shards = (
            program.num_model_shards(cfg.mesh, self._model_axes)
            if self._model_axes is not None else 1)
        self._num_shards = (
            sharded_mod.num_edge_shards(cfg.mesh, cfg.edge_axes)
            if cfg.mesh is not None else 1)
        self._sessions: dict[str, _Session] = {}
        self._compiled: dict[tuple, object] = {}
        self._admitted = 0
        self._probes_run = 0
        # scheduler/work accounting: program invocations and the
        # device-work slots they spent (occupancy x solver steps) — the
        # witnesses for "converged sessions cost zero device work".
        self._tick_invocations = 0
        self._device_work = 0
        self._multiplied_ticks = 0  # invocations the scheduler stretched
        # per-class degree map memo: degrees only move on admission /
        # eviction / re-plans, so status sweeps (session_info per
        # tenant) must not rebuild the map per session — O(S^2) fleets
        self._class_degree_cache: dict[tuple, int] | None = None

    def _get(self, sid: str) -> _Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise UnknownSessionError(sid) from None

    def has_session(self, sid: str) -> bool:
        return sid in self._sessions

    def session_ids(self) -> list[str]:
        return list(self._sessions)

    def _balanced(self, capacity: int) -> int:
        """Edge capacity rounded up to a shard-balanced size."""
        from repro.stream import sharded as sharded_mod

        if self._num_shards <= 1:
            return capacity
        return sharded_mod.balanced_capacity(capacity, self._num_shards)

    # ------------------------------------------------------------------
    # spectral probing + dilation planning
    # ------------------------------------------------------------------

    def _rho_estimate(self, store: gs.GraphStore, n: int) -> tuple:
        """(refreshed store, rho, rho_ub, lam_k, lam_k1) — plan anchors.

        rho is the SLQ lambda_max estimate capped by the Gershgorin
        bound (the bound is certain, the probe is not); with probing
        disabled — or a degenerate probe — it IS the bound, which keeps
        this path jit-friendly and dependency-free.  lam_k/lam_k1 are
        the probed bottom-edge eigenvalues (None without a probe),
        feeding the planner's strength/degree selection in
        `_plan_session`.  Probe compiles are shared per capacity class
        (fixed edge/node shapes, traced n).
        """
        cfg = self.cfg
        store, rho_ub = gs.spectral_radius_upper_bound(store)
        rho_ub = float(rho_ub)
        rho = rho_ub
        lam_k = lam_k1 = None
        if cfg.probe_spectrum and n > 1:
            self._probes_run += 1
            probe_key = jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed + 7), self._probes_run)
            if self._model_axes is not None:
                # Panel-sharded serving probes through the row-sharded
                # matvec (owned rows per shard, one psum assembly) —
                # the same decomposition the model tick runs.  The
                # probe-time blocking is throwaway (the session builds
                # its own on first tick); probes only run on admission
                # and drift re-solves, so the host-side rebucket is off
                # the tick path.
                mb = gs.model_sharded_blocking(
                    store, self._model_shards,
                    block_n=cfg.tick_block_n)
                probe = spectral_probes.probe_model_sharded(
                    self._mesh, mb, probe_key,
                    jnp.asarray(n, jnp.int32),
                    model_axes=self._model_axes,
                    num_probes=cfg.probe_vectors,
                    num_steps=cfg.probe_steps,
                    backend=self._backend,
                )
            elif self._mesh is not None:
                # Sharded serving probes through the SAME psum-assembled
                # matvec the tick programs run, so the rho anchoring the
                # per-session dilation rescale is measured per shard and
                # agrees with single-device serving up to collective
                # summation order.
                probe = spectral_probes.probe_sharded_edge_arrays(
                    self._mesh, store.src, store.dst, store.weight,
                    probe_key, jnp.asarray(n, jnp.int32),
                    num_nodes=store.num_nodes,
                    edge_axes=cfg.edge_axes,
                    num_probes=cfg.probe_vectors,
                    num_steps=cfg.probe_steps,
                    backend=self._backend,
                )
            else:
                probe = spectral_probes.probe_edge_arrays(
                    store.src, store.dst, store.weight, probe_key,
                    jnp.asarray(n, jnp.int32),
                    num_nodes=store.num_nodes,
                    num_probes=cfg.probe_vectors,
                    # NOT clamped to n: probe_steps is jit-static, and
                    # the Lanczos recurrence handles m >= n via sticky
                    # breakdown, so the compile stays shared across the
                    # capacity class.
                    num_steps=cfg.probe_steps,
                    backend=self._backend,
                )
            est = float(probe.lambda_max)
            if np.isfinite(est) and est > 0.0:
                rho = min(est, rho_ub)
                lam_k, lam_k1 = spectral_probes.bottom_edge(probe, cfg.k)
        return store, rho, rho_ub, lam_k, lam_k1

    def _plan_session(self, sess: _Session, rho: float, rho_ub: float,
                      lam_k: float | None = None,
                      lam_k1: float | None = None) -> None:
        """Re-run the dilation planner on fresh probe anchors.

        The plan carries the session's whole solve schedule: strength
        tau (capped by the wanted-decay guard and
        ``cfg.dilation_strength``), the degree suggestion (snapped onto
        the planner grid, capped by the ``cfg.degree`` budget — the
        class degree is the max over its members' suggestions), and the
        per-session lr (normalized to the plan's wanted-direction scale
        — see ``core.program.session_lr``).
        """
        cfg = self.cfg
        sess.plan = plan_mod.plan_dilation(
            None, k=cfg.k, budget=cfg.degree,
            rho_fallback=rho_ub,
            rho=rho if rho > 0.0 else None,
            lam_k=lam_k, lam_k1=lam_k1,
            tau_cap=cfg.dilation_strength,
            families=_TICK_FAMILIES,
            source="slq" if lam_k is not None else "fallback")
        sess.rho_ub = rho_ub
        sess.plan_degree = (1 if sess.plan.family == "identity"
                            else sess.plan.degree)
        # step size normalized to the plan's WANTED-direction scale
        # (core.program.session_lr): strongly dilated tenants take
        # proportionally larger steps — the lr rides traced, so the
        # per-session values share one compiled program
        sess.lr = program.session_lr(sess.plan, cfg.lr)
        sess.rate = None  # operator changed: stale decay forecast
        self._class_degree_cache = None  # degree suggestion may move

    def _shift_rho(self, sess: _Session, rho_new: float,
                   rho_ub_new: float) -> None:
        """Ordinary-batch rescale: move the plan's rho anchor without
        re-probing (no probe matvecs).  Degenerate plans (edgeless
        admission, rho == 0) re-plan from the fresh bound instead — the
        ratio tracking would pin rho at 0 forever."""
        if sess.plan.rho <= 0.0 or not math.isfinite(sess.plan.rho):
            self._plan_session(sess, rho_new, rho_ub_new)
            return
        repl = {"rho": rho_new}
        if sess.plan.family == "identity":
            repl["lambda_star"] = plan_mod.identity_lambda_star(rho_new)
        sess.plan = dataclasses.replace(sess.plan, **repl)
        sess.rho_ub = rho_ub_new
        # the wanted-direction scale moved with rho: re-derive the lr
        # boost (the only other plan-derived session field)
        sess.lr = program.session_lr(sess.plan, self.cfg.lr)

    # ------------------------------------------------------------------
    # admission / eviction
    # ------------------------------------------------------------------

    def add_graph(self, sid: str, g, num_clusters: int | None = None,
                  edge_capacity: int | None = None,
                  resume_panel=None) -> None:
        """Admit a graph into its capacity class.

        ``resume_panel`` warm-starts the session from a previously
        evicted panel (the ``panel`` entry of :meth:`evict`'s summary):
        the panel is re-orthonormalized through
        ``solvers.init_from_panel`` onto the class's node padding, so a
        re-admitted tenant reconverges in a fraction of the cold ticks.
        """
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} already exists")
        cfg = self.cfg
        clusters = num_clusters or cfg.num_clusters
        need = clusters + (1 if cfg.drop_trivial else 0)
        if need > cfg.k:
            raise ValueError(
                f"num_clusters={clusters} needs {need} tracked "
                f"eigenvectors (drop_trivial={cfg.drop_trivial}) but "
                f"ServiceConfig.k={cfg.k}")
        node_cap = node_capacity_class(g.num_nodes)
        cap = (gs.capacity_class(g.num_edges) if edge_capacity is None
               else edge_capacity)
        store = gs.from_edge_list(g, capacity=self._balanced(cap),
                                  num_nodes=node_cap)
        store, rho, rho_ub, lam_k, lam_k1 = self._rho_estimate(
            store, g.num_nodes)
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                 self._admitted)
        self._admitted += 1
        if resume_panel is not None:
            rp = jnp.asarray(resume_panel, jnp.float32)
            if rp.shape != (g.num_nodes, cfg.k):
                raise ValueError(
                    f"resume_panel shape {rp.shape} != "
                    f"({g.num_nodes}, {cfg.k})")
            v = jnp.zeros((node_cap, cfg.k), jnp.float32).at[
                : g.num_nodes].set(rp)
            v = solvers.init_from_panel(v).v
        else:
            v = _init_panel(key, node_cap, g.num_nodes, cfg.k)
        sess = _Session(
            sid=sid,
            n=g.num_nodes,
            num_clusters=clusters,
            store=store,
            v=v,
            plan=plan_mod.plan_dilation(None, k=cfg.k, budget=cfg.degree),
            rho_ub=rho_ub,
            lr=cfg.lr,
            plan_degree=1,
            tracker=tracking.LabelTracker(clusters),
        )
        self._plan_session(sess, rho, rho_ub, lam_k, lam_k1)
        sess.solves = 1  # the admission (cold or resumed) solve
        self._sessions[sid] = sess
        self._class_degree_cache = None  # fleet membership changed

    def evict(self, sid: str) -> dict:
        """Remove a session; returns its summary, including the live
        eigenvector ``panel`` (real rows only) so a later re-admission
        can warm-start through ``add_graph(resume_panel=...)``.

        Raises :class:`UnknownSessionError` on an unknown or
        already-evicted sid (evict is not idempotent: the second call
        reports the id as gone instead of silently succeeding)."""
        sess = self._get(sid)
        # summarize BEFORE removal so the reported degree is the one the
        # session actually solved under (it may anchor its class's max)
        summary = self._summary(sess)
        summary["panel"] = np.asarray(sess.v[: sess.n])
        del self._sessions[sid]
        self._class_degree_cache = None  # fleet membership changed
        return summary

    def evict_converged(self) -> dict[str, dict]:
        """Drop every converged session (label consumers are done)."""
        done = [s for s in self._sessions.values() if s.converged]
        return {s.sid: self.evict(s.sid) for s in done}

    # ------------------------------------------------------------------
    # streaming updates
    # ------------------------------------------------------------------

    def apply_updates(self, sid: str, edges, weights,
                      mode: str = "set",
                      pad_to: int | None = None) -> gs.BatchStats:
        """Apply an edge batch; converged sessions take the first-order
        eigen-update path, falling back to a warm re-solve on drift.

        ``pad_to`` lets a caller draining many sessions at once (the
        serve engine's per-capacity-class drain) pin one batch pad for
        a whole class, so every session in the class hits the SAME
        compiled apply instead of one compile per pow2 batch size."""
        cfg = self.cfg
        sess = self._get(sid)
        pad = max(_next_pow2(len(np.atleast_1d(weights))),
                  cfg.min_batch_pad)
        if pad_to is not None:
            pad = max(pad, _next_pow2(pad_to))
        batch = gs.coalesce_batch(edges, weights, mode=mode, pad_to=pad)
        store, dw, stats = gs.apply_edge_batch(sess.store, batch, mode=mode)
        base = sess.store
        while int(stats.dropped) > 0:
            # buffer overflow: grow the ORIGINAL store (untouched —
            # apply is functional) and re-apply the whole batch, growing
            # again until nothing drops (a batch can exceed one ladder
            # step).  The session changes capacity class, so its next
            # tick joins a different group.  Sharded serving keeps the
            # grown capacity a multiple of the shard count.
            base = gs.grow(base)
            if base.capacity != self._balanced(base.capacity):
                base = gs.grow(base, self._balanced(base.capacity))
            store, dw, stats = gs.apply_edge_batch(base, batch, mode=mode)
        # Ordinary batches rescale cheaply: track the probed estimate by
        # the Gershgorin bound's relative change (no probe matvecs), cap
        # by the fresh bound.  Full re-probes happen on admission and on
        # the drift-triggered re-solve below.
        store, rho_ub = gs.spectral_radius_upper_bound(store)
        rho_ub_new = float(rho_ub)
        sess.store = store
        # edge mutation stales the blocked layouts (single, sharded,
        # and model-sharded), the measured residual-decay rate
        # (operator changed), and — when the buffer grew a capacity
        # class — the degree map
        sess.blocking = None
        sess.sharded_blocking = None
        sess.model_blocking = None
        sess.rate = None
        self._class_degree_cache = None
        if sess.rho_ub > 0.0:
            rho_new = min(rho_ub_new,
                          sess.plan.rho * rho_ub_new / sess.rho_ub)
        else:
            # degenerate (edgeless) admission: rho == rho_ub == 0, and
            # the ratio would pin rho at 0 forever (c -> 1/eps -> NaN
            # panels); re-anchor on the fresh bound instead
            rho_new = rho_ub_new
        self._shift_rho(sess, rho_new, rho_ub_new)
        if sess.est is not None:
            prev_v = sess.est.v
            est, drift_flag = updates.update_or_flag(
                sess.est, batch.src, batch.dst, dw,
                updates.UpdateConfig(fallback_ratio=cfg.fallback_ratio))
            sess.v = est.v
            sess.incremental_updates += 1
            if not drift_flag:
                sess.est = est  # cheap path: drift bound still safe
                # The drift bound guards first-order VALIDITY, not the
                # residual target: a converged session absorbing real
                # weight deltas can sit well above tolerance while the
                # bound stays "safe" (large eigengap => large drift
                # budget), and because converged sessions left their
                # tick groups entirely, it would NEVER be re-solved —
                # every later batch silently staged against a stale
                # panel.  Verify with one operator application and
                # re-enter the tick rotation when the panel misses
                # tolerance; a genuinely realized no-op (dw == 0, e.g.
                # a reweight to the current value) skips the check and
                # keeps convergence verbatim.
                if sess.converged and bool(np.any(np.asarray(dw) != 0.0)):
                    res = float(self._residual(sess))
                    sess.residual = res
                    if res > cfg.tol:
                        sess.converged = False
                        sess.est = None  # ticking owns the panel again
                return stats
            # The drift bound is conservative (Σ 2|dw| vs the min
            # PANEL gap, which bulk eigenvalues make tiny) — so before
            # paying for a re-solve, VERIFY with one operator
            # application: does the updated panel still meet tolerance
            # under the new operator?
            res = float(self._residual(sess))
            sess.residual = res
            if res <= 2.0 * cfg.tol:
                # panel survived: re-anchor the estimate (drift resets)
                st = sess.store
                sess.est = updates.anchor_estimate_arrays(
                    st.src, st.dst, st.weight, sess.v)
                return stats
            # Full SPED re-solve.  The accumulated drift that invalidated
            # the panel also staled the admission-time lambda_max, so
            # RE-PROBE the spectrum and re-run the dilation planner
            # before deciding how to seed the solve.  A first-order
            # update outside its validity region can be WORSE than the
            # stale panel, so seed from whichever candidate has the
            # lower residual under the new (re-planned) operator; go
            # cold when even that fails the restart test (stream.warm).
            sess.fallbacks += 1
            sess.est = None
            sess.converged = False
            st2, rho2, rho_ub2, lam_k2, lam_k12 = self._rho_estimate(
                sess.store, sess.n)
            sess.store = st2
            self._plan_session(sess, rho2, rho_ub2, lam_k2, lam_k12)
            res = float(self._residual(sess))  # est.v under re-probed op
            sess.v = prev_v
            res_prev = float(self._residual(sess))
            if res <= res_prev:
                sess.v, best = est.v, res
            else:
                best = res_prev
            if best > cfg.restart_residual:
                key = jax.random.fold_in(
                    jax.random.PRNGKey(cfg.seed + 1), sess.solves)
                sess.v = _init_panel(key, sess.store.num_nodes,
                                     sess.n, cfg.k)
            sess.residual = best
            sess.solves += 1
        return stats

    # ------------------------------------------------------------------
    # batched ticking
    # ------------------------------------------------------------------

    def _class_key(self, sess: _Session) -> tuple[int, int]:
        return (sess.store.num_nodes, sess.store.capacity)

    def _class_degrees(self) -> dict[tuple, int]:
        """Per-capacity-class dilation degree: the max over the class's
        resident exp-family sessions' planned suggestions (snapped onto
        the planner grid by construction, capped by ``cfg.degree``).

        This IS the per-class degree re-plan: a newly admitted (or
        drift-re-probed) tenant whose plan needs more dilation raises
        its class's degree — a new compile key, but only on the snapped
        degree set (`core.program.schedule_degrees`).  Identity-family
        sessions stay out: they tick in their own degree-1 groups.
        Memoized until admission/eviction/re-plan invalidates it.
        """
        if self._class_degree_cache is None:
            degs: dict[tuple, int] = {}
            for s in self._sessions.values():
                if s.plan.family == "identity":
                    continue
                ck = self._class_key(s)
                degs[ck] = max(degs.get(ck, 0), s.plan_degree)
            self._class_degree_cache = degs
        return self._class_degree_cache

    def _session_degree(self, sess: _Session,
                        degrees: dict | None = None) -> int:
        if sess.plan.family == "identity":
            return 1
        degrees = self._class_degrees() if degrees is None else degrees
        return degrees.get(self._class_key(sess), sess.plan_degree)

    def _ensure_blocking(self, sess: _Session) -> None:
        """Build (or rebuild after updates) the session's blocked
        layout for its tick path — host-side, cached on the session.
        Edge-sharded serving builds the per-shard variant; panel
        sharding builds the destination-aligned model layout (used by
        BOTH backends — the model tick's segment path scatters over the
        same per-shard arrays the kernel consumes)."""
        if self._model_axes is not None:
            if sess.model_blocking is None:
                sess.model_blocking = gs.model_sharded_blocking(
                    sess.store, self._model_shards,
                    block_n=self.cfg.tick_block_n)
        elif self._mesh is not None:
            if sess.sharded_blocking is None:
                sess.sharded_blocking = gs.sharded_node_blocking(
                    sess.store, self._num_shards,
                    block_n=self.cfg.tick_block_n)
        elif sess.blocking is None:
            sess.blocking = gs.node_blocking(
                sess.store, block_n=self.cfg.tick_block_n)

    def _group_key(self, sess: _Session, degrees: dict | None = None
                   ) -> tuple:
        """Sessions sharing a group share one compiled tick program.

        Groups by capacity class + scheduled dilation degree; pallas
        additionally groups by the blocking's static layout (block size
        and pow2-snapped chunk count), since those are the shapes the
        kernel compiles against — sharded pallas uses the per-shard
        layout's statics the same way.  Only ACTIVE (unconverged)
        sessions are ever grouped, so a converged session's invalidated
        blocking is never rebuilt just to anchor a bucket.
        """
        deg = self._session_degree(sess, degrees)
        if self._model_axes is not None:
            # panel sharding needs the layout statics on BOTH backends
            # (the model tick's segment path runs over the same arrays)
            self._ensure_blocking(sess)
            b = sess.model_blocking
            key = (self._class_key(sess), deg, b.block_n,
                   b.num_chunks, b.block_e)
        elif self._backend == "pallas":
            self._ensure_blocking(sess)
            b = (sess.sharded_blocking if self._mesh is not None
                 else sess.blocking)
            key = (self._class_key(sess), deg, b.block_n,
                   b.num_chunks, b.block_e)
        else:
            key = (self._class_key(sess), deg)
        sess.group_key = key
        return key

    def _get_step(self, key: tuple, occupancy: int):
        cfg = self.cfg
        fn = self._compiled.get((key, occupancy))
        if fn is None:
            # lr is NOT part of the schedule here: tick programs take
            # the per-session learning rates as a traced input
            schedule = program.StepSchedule(
                method=cfg.method, degree=key[1],
                steps=cfg.steps_per_tick, backend=self._backend)
            has_layout = (self._backend == "pallas"
                          or self._model_axes is not None)
            layout = key[2:] if has_layout else None
            fn = program.build_tick_program(
                schedule, layout=layout, mesh=self._mesh,
                edge_axes=cfg.edge_axes, model_axes=self._model_axes)
            self._compiled[(key, occupancy)] = fn
        return fn

    @property
    def compile_count(self) -> int:
        """Distinct compiled tick programs — (capacity class, degree,
        layout) x pow2 occupancy bucket, so the count stays logarithmic
        in fleet size (the schedule-plumbing invariant's witness).  The
        scheduler's tick multiplier and every per-session hyperparameter
        are traced: they add NO programs."""
        return len(self._compiled)

    @property
    def tick_invocations(self) -> int:
        """Compiled tick-program invocations so far (all groups)."""
        return self._tick_invocations

    @property
    def device_work(self) -> int:
        """Accumulated device work in session-slot solver steps
        (occupancy x steps per invocation).  Converged sessions leave
        their groups, so they contribute ZERO here — the counter the
        zero-work-when-converged tests assert on."""
        return self._device_work

    @property
    def multiplied_ticks(self) -> int:
        """Invocations the residual-decay scheduler stretched past one
        plain tick (traced chunk multiplier > 1 — zero extra compiles)."""
        return self._multiplied_ticks

    def _tick_multipliers(self, members: list[_Session]) -> np.ndarray:
        """Residual-decay scheduling: PER-SESSION steps multipliers.

        Each member's own forecast (measured decay rate, see
        ``core.program.contraction_rate``) sizes its own chunk budget:
        a member predicted to stay above tolerance for more than
        ``eval_payoff`` plain ticks stretches to ``min(predicted plain
        ticks, max_tick_multiplier)`` — floored, so nobody overshoots
        their forecast — while a member near convergence (or with no
        usable forecast yet) keeps multiplier 1 and freezes after its
        own budget inside the shared program (``core.program``'s
        per-session chunk mask).  Before this split the group took ONE
        multiplier ``min``-ed over members, so the soonest-converging
        (or merely forecast-less) session capped every peer at plain
        ticks.  The multipliers ride as a traced ``(G,)`` input, so any
        mix reuses the group's one compiled program; ``tick`` then
        sub-batches plain members away from stretched ones when that
        executes fewer slot-steps (``_split_by_multiplier``).
        """
        cfg = self.cfg
        mults = np.ones(len(members), np.int64)
        if (cfg.tick_schedule != "residual_decay"
                or cfg.max_tick_multiplier <= 1):
            return mults
        for i, m in enumerate(members):
            if m.rate is None or not (0.0 < m.rate < 1.0):
                continue
            need = program.predicted_steps_to_tol(m.residual, m.rate,
                                                  cfg.tol)
            if need <= cfg.eval_payoff * cfg.steps_per_tick:
                continue
            mult = max(1, min(need // cfg.steps_per_tick,
                              cfg.max_tick_multiplier))
            if m.residual <= cfg.stretch_residual_floor * cfg.tol:
                mult = min(mult, 4)  # endgame cap (see config)
            mults[i] = mult
        return mults

    def tick(self) -> dict[str, float]:
        """Advance every unconverged session one scheduled tick — one
        compiled program invocation per (capacity class, degree) group
        (and, on pallas, per blocking layout), or two when the
        scheduler sub-batches plain members away from stretched ones
        (``_split_by_multiplier``).  Converged sessions are not grouped
        at all: zero device work."""
        cfg = self.cfg
        degrees = self._class_degrees()
        groups: dict[tuple, list[_Session]] = defaultdict(list)
        for sess in self._sessions.values():
            if not sess.converged:
                groups[self._group_key(sess, degrees)].append(sess)
        out: dict[str, float] = {}
        for gkey, g_members in groups.items():
            deg = gkey[1]
            g_mults = self._tick_multipliers(g_members)
            for members, mults in _split_by_multiplier(g_members, g_mults):
                # occupancy bucket follows the ACTIVE member count (pow2
                # padded with replicas of the first session): converged
                # sessions no longer ride along as padding, at the cost of
                # at most log2(max occupancy) compiled buckets per group
                occ = _next_pow2(len(members))
                max_mult = int(mults.max())
                step = self._get_step(gkey, occ)
                idx = list(range(len(members))) + [0] * (occ - len(members))
                stack = lambda f: jnp.stack([f(members[i]) for i in idx])
                cs = jnp.asarray(
                    [program.dilation_scale(members[i].plan, deg)
                     for i in idx], jnp.float32)
                lrs = jnp.asarray([members[i].lr for i in idx], jnp.float32)
                # traced per-session chunk budgets: no recompile for any mix
                chunks = jnp.asarray(mults[np.asarray(idx)], jnp.int32)
                if self._model_axes is not None:
                    vs, res = step(
                        stack(lambda s: s.model_blocking.u_local),
                        stack(lambda s: s.model_blocking.other),
                        stack(lambda s: s.model_blocking.weight),
                        stack(lambda s: s.model_blocking.chunk_block),
                        stack(lambda s: s.model_blocking.deg),
                        stack(lambda s: s.v), cs, lrs, chunks)
                elif self._backend == "pallas" and self._mesh is not None:
                    vs, res = step(
                        stack(lambda s: s.sharded_blocking.u_local),
                        stack(lambda s: s.sharded_blocking.other),
                        stack(lambda s: s.sharded_blocking.weight),
                        stack(lambda s: s.sharded_blocking.chunk_block),
                        stack(lambda s: s.sharded_blocking.deg),
                        stack(lambda s: s.v), cs, lrs, chunks)
                elif self._backend == "pallas":
                    vs, res = step(
                        stack(lambda s: s.blocking.u_local),
                        stack(lambda s: s.blocking.other),
                        stack(lambda s: s.blocking.weight),
                        stack(lambda s: s.blocking.chunk_block),
                        stack(lambda s: s.blocking.deg),
                        stack(lambda s: s.v), cs, lrs, chunks)
                else:
                    # single-device segment AND sharded segment take the
                    # same stacked-edge-buffer signature (the sharded
                    # builder shards the capacity axis over the mesh)
                    vs, res = step(
                        stack(lambda s: s.store.src),
                        stack(lambda s: s.store.dst),
                        stack(lambda s: s.store.weight),
                        stack(lambda s: s.v), cs, lrs, chunks)
                self._tick_invocations += 1
                # device work is what the hardware executes: every occupancy
                # slot rides the longest member's chunk budget (short-budget
                # members freeze under the mask but their slots still step)
                self._device_work += occ * cfg.steps_per_tick * max_mult
                if max_mult > 1:
                    self._multiplied_ticks += 1
                res = np.asarray(res)
                for i, sess in enumerate(members):
                    prev = sess.residual
                    sess.v = vs[i]
                    sess.residual = float(res[i])
                    # fresh decay estimate over the member's OWN executed
                    # step count (its panel froze after its chunk budget);
                    # a non-contracting observation resets the forecast
                    # (the scheduler then stays at plain ticks until
                    # contraction re-establishes)
                    sess.rate = program.contraction_rate(
                        prev, sess.residual,
                        cfg.steps_per_tick * int(mults[i]))
                    sess.ticks += 1
                    out[sess.sid] = sess.residual
                    if sess.residual <= cfg.tol:
                        sess.converged = True
                        st = sess.store
                        sess.est = updates.anchor_estimate_arrays(
                            st.src, st.dst, st.weight, sess.v)
        return out

    @property
    def all_converged(self) -> bool:
        return all(s.converged for s in self._sessions.values())

    def run_until_converged(self, max_ticks: int = 500) -> int:
        """Tick until every session converges; returns ticks used.

        Check `all_converged` afterwards: hitting the tick budget without
        converging also returns (with the budget spent), and serving
        labels from an unconverged panel is the caller's decision.
        Converged sessions cost zero device work here — their groups
        shrink away — so waiting on a slow tenant never re-runs the
        finished ones.
        """
        used = 0
        while not self.all_converged and used < max_ticks:
            self.tick()
            used += 1
        return used

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def _residual(self, sess: _Session) -> float:
        st = sess.store
        deg = self._session_degree(sess)
        c = program.dilation_scale(sess.plan, deg)
        return float(operators.dilated_panel_residual(
            st.src, st.dst, st.weight, sess.v, c, deg))

    def live_edges(self, sid: str) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, weight) of the session's live edges — the public
        view of the store for consumers building update batches."""
        st = self._get(sid).store
        w = np.asarray(st.weight)
        live = w != 0
        return np.asarray(st.src)[live], np.asarray(st.dst)[live], w[live]

    def panel(self, sid: str) -> jax.Array:
        """The session's live eigenvector panel (real rows only) — the
        immutable embedding snapshot the serving layer commits per
        result version (repro.serve.results)."""
        sess = self._get(sid)
        return sess.v[: sess.n]

    def labels(self, sid: str) -> np.ndarray:
        """Current cluster assignment with STABLE ids (tracking.py)."""
        cfg = self.cfg
        sess = self._get(sid)
        raw = panel_labels(
            sess.v[: sess.n], sess.num_clusters,
            drop_trivial=cfg.drop_trivial, seed=cfg.seed,
            kmeans_restarts=cfg.kmeans_restarts)
        return np.asarray(sess.tracker.update(raw))

    def capacity_class(self, sid: str) -> tuple[int, int]:
        """(node capacity, edge capacity) of the session's class — the
        serve layer's drain-batching group key (sessions in one class
        share the compiled edge-batch apply at a common pad)."""
        return self._class_key(self._get(sid))

    def session_info(self, sid: str) -> dict:
        return self._summary(self._get(sid))

    def _summary(self, sess: _Session) -> dict:
        return {
            "n": sess.n,
            "node_capacity": sess.store.num_nodes,
            "edge_capacity": sess.store.capacity,
            "num_edges": int(gs.num_edges(sess.store)),
            "converged": sess.converged,
            "residual": sess.residual,
            "rho": sess.rho,
            "rho_ub": sess.rho_ub,
            "tau": sess.tau,
            "family": sess.plan.family,
            "degree": self._session_degree(sess),
            "lr": sess.lr,
            "rate": sess.rate,
            "ticks": sess.ticks,
            "solves": sess.solves,
            "incremental_updates": sess.incremental_updates,
            "fallbacks": sess.fallbacks,
        }
