"""Multi-tenant streaming clustering service.

Owns many mutable graphs (stream.graph_store), each with a live
eigenvector panel, and advances them with BATCHED jitted ticks:

  * Sessions are grouped by CAPACITY CLASS — (node_cap, edge_cap) — and
    every group tick is ONE compiled program vmapped over the group's
    stacked edge buffers and panels.  Shapes never depend on a session's
    live edge count or real node count, so admitting graph #9 to a class
    that already ticked reuses the compiled step (no per-session
    recompilation).  Groups are padded to power-of-two occupancy with
    replicas of the first session, so evictions only recompile when the
    occupancy bucket changes (log2 many programs per class, ever).
  * The per-session operator is the dilated reversed Laplacian
    (I - c L)^degree — the paper's limit_neg_exp series with λ* = 0 —
    with the dilation scale c = strength / (ρ · degree) a TRACED
    per-session input (different graphs, one program).  ρ is the SLQ
    lambda_max estimate (repro.spectral), probed on admission and on
    drift-triggered re-solves and capped by the Gershgorin
    2·max-degree bound; the bound alone anchors the scale when probing
    is disabled.
  * Per-session convergence is the ground-truth-free panel residual;
    converged sessions leave the tick rotation, get their eigen estimate
    anchored (stream.updates), and serve labels until edge updates
    arrive.  Updates take the cheap first-order eigen-update path and
    only re-enter the solve rotation when accumulated drift triggers the
    fallback, warm-started per stream.warm's restart test.

Node padding invariant: panels keep EXACT zeros on rows >= the session's
real node count.  No edge ever touches a padding node, and every solver
operation (edge matvec, series recurrence, QR, normalization) maps zero
rows to zero rows, so the padded problem is numerically identical to the
unpadded one.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_mod
from repro.core import kmeans as km
from repro.core import laplacian as lap
from repro.core import metrics, solvers
from repro.kernels.edge_spmm import ops as es_ops
from repro.spectral import probes as spectral_probes
from repro.stream import graph_store as gs
from repro.stream import tracking, updates, warm


_next_pow2 = es_ops.next_pow2


def node_capacity_class(num_nodes: int) -> int:
    """Node-count capacity class (power of two >= num_nodes)."""
    return max(_next_pow2(num_nodes), 64)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    k: int = 6  # eigenvectors tracked per session
    num_clusters: int = 4  # default clusters served per session
    method: str = "mu_eg"  # solver step: "mu_eg" | "oja"
    lr: float = 0.3
    degree: int = 15  # odd; series degree of the dilation polynomial
    dilation_strength: float = 8.0
    steps_per_tick: int = 20  # solver steps per session per tick
    tol: float = 2e-3  # panel-residual convergence target
    restart_residual: float = 0.6  # warm.py restart test
    fallback_ratio: float = 0.5  # updates.py drift fallback
    min_batch_pad: int = 16  # update batches pad to pow2 >= this
    drop_trivial: bool = True  # skip the all-ones nullvector in embeddings
    kmeans_restarts: int = 8
    seed: int = 0
    # SLQ spectral probing (repro.spectral): a tight lambda_max estimate
    # replaces the Gershgorin 2*max_degree bound when setting the
    # dilation scale — the bound over-estimates by ~2x on dense graphs,
    # silently halving the dilation.  Probes run on session admission
    # and on drift-triggered re-solves; ordinary update batches keep the
    # cheap bound-only rescale.  The bound always survives as cap (it is
    # certain; the probe is not) and as fallback when probing is off.
    probe_spectrum: bool = True
    probe_vectors: int = 2  # SLQ probe vectors per (re-)probe
    probe_steps: int = 16  # Lanczos steps per probe vector
    # Matvec backend for tick programs and probes (repro.core.backend):
    # "auto" = pallas on TPU, segment elsewhere.  Pallas ticks run the
    # node-blocked incidence-SpMM kernel with the dilation step fused
    # into its epilogue; the per-session blocking is built on admission
    # and rebuilt after edge updates (graph_store.node_blocking), and
    # sessions group by (capacity class, blocking chunk count) — the
    # chunk count is pow2-snapped, so compile counts stay logarithmic.
    backend: str = "auto"
    tick_block_n: int = 512  # node-block rows per VMEM panel slice
    # Device mesh for SHARDED serving (stream.sharded): when set, every
    # capacity-class tick runs as one shard_mapped fused series program
    # with the class's edge buffers (segment) or per-shard node
    # blockings (pallas) partitioned over `edge_axes`, one psum of the
    # stacked panels per dilation matvec, and admission probes routed
    # through the same sharded matvec.  Admission/growth round edge
    # capacities up to a multiple of the shard count so shard slices
    # stay balanced.  None = single-device ticks (the default).
    mesh: object | None = None
    edge_axes: tuple = ("data",)

    def __post_init__(self):
        if self.degree % 2 == 0:
            raise ValueError("degree must be odd (limit_neg_exp series)")
        if self.backend not in backend_mod.BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.mesh is not None:
            missing = [a for a in self.edge_axes
                       if a not in self.mesh.axis_names]
            if missing:
                raise ValueError(
                    f"edge_axes {missing} not in mesh axes "
                    f"{self.mesh.axis_names}")


@dataclasses.dataclass
class _Session:
    sid: str
    n: int  # real node count (<= store.num_nodes == node capacity)
    num_clusters: int
    store: gs.GraphStore
    v: jax.Array  # (node_cap, k) panel, zero rows >= n
    c: float  # dilation scale per matvec
    rho: float  # spectral-radius estimate anchoring c (probed or bound)
    rho_ub: float  # Gershgorin bound at the time rho was set
    tau: float  # effective dilation strength (config, capped per probe)
    tracker: tracking.LabelTracker
    blocking: es_ops.NodeBlocking | None = None  # pallas tick layout cache
    # per-shard layout cache for sharded pallas ticks (stream.sharded);
    # invalidated together with `blocking` on edge mutations
    sharded_blocking: es_ops.ShardedNodeBlocking | None = None
    group_key: tuple | None = None  # last tick-group key (occupancy anchor)
    est: updates.EigenEstimate | None = None
    converged: bool = False
    residual: float = float("inf")
    ticks: int = 0
    solves: int = 0  # full (re-)solve episodes entered
    incremental_updates: int = 0
    fallbacks: int = 0


_edge_mv = lap.edge_matvec_arrays


@functools.partial(jax.jit, static_argnames=("degree",))
def _op_apply(src, dst, w, v, c, degree):
    """(I - c L)^degree V — the dilated reversed operator, one session."""
    def body(_, u):
        return u - c * _edge_mv(src, dst, w, u)
    return jax.lax.fori_loop(0, degree, body, v)


@functools.partial(jax.jit, static_argnames=("degree",))
def _op_residual(src, dst, w, v, c, degree):
    av = _op_apply(src, dst, w, v, c, degree)
    return metrics.panel_residual(v, av)


@jax.jit
def _anchor_estimate(src, dst, w, v):
    """λ = diag(Vᵀ L V) on the store's padded edge buffer."""
    return updates.estimate_from_panel(
        lambda x: _edge_mv(src, dst, w, x), v)


@functools.partial(jax.jit, static_argnames=("node_cap", "n", "k"))
def _init_panel(key, node_cap: int, n: int, k: int):
    """Random orthonormal panel supported on the first n rows."""
    v = jax.random.normal(key, (node_cap, k), jnp.float32)
    v = v * (jnp.arange(node_cap) < n)[:, None]
    q, _ = jnp.linalg.qr(v)
    return q


class StreamingService:
    """Session manager: admission, streaming updates, batched ticking,
    label serving, eviction."""

    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        from repro.stream import sharded as sharded_mod

        self.cfg = cfg
        self._backend = backend_mod.resolve_backend(cfg.backend)
        self._mesh = cfg.mesh
        self._num_shards = (
            sharded_mod.num_edge_shards(cfg.mesh, cfg.edge_axes)
            if cfg.mesh is not None else 1)
        self._sessions: dict[str, _Session] = {}
        self._compiled: dict[tuple, object] = {}
        self._admitted = 0
        self._probes_run = 0

    def _balanced(self, capacity: int) -> int:
        """Edge capacity rounded up to a shard-balanced size."""
        from repro.stream import sharded as sharded_mod

        if self._num_shards <= 1:
            return capacity
        return sharded_mod.balanced_capacity(capacity, self._num_shards)

    # ------------------------------------------------------------------
    # spectral probing
    # ------------------------------------------------------------------

    def _rho_estimate(self, store: gs.GraphStore, n: int
                      ) -> tuple[gs.GraphStore, float, float, float | None]:
        """(refreshed store, rho, rho_ub, lam_k) — the dilation anchors.

        rho is the SLQ lambda_max estimate capped by the Gershgorin
        bound (the bound is certain, the probe is not); with probing
        disabled — or a degenerate probe — it IS the bound, which keeps
        this path jit-friendly and dependency-free.  lam_k is the probed
        k-th-smallest eigenvalue (None without a probe), feeding the
        planner's over-dilation cap in `_set_scale`.  Probe compiles are
        shared per capacity class (fixed edge/node shapes, traced n).
        """
        cfg = self.cfg
        store, rho_ub = gs.spectral_radius_upper_bound(store)
        rho_ub = float(rho_ub)
        rho = rho_ub
        lam_k = None
        if cfg.probe_spectrum and n > 1:
            self._probes_run += 1
            probe_key = jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed + 7), self._probes_run)
            if self._mesh is not None:
                # Sharded serving probes through the SAME psum-assembled
                # matvec the tick programs run, so the rho anchoring the
                # per-session dilation rescale is measured per shard and
                # agrees with single-device serving up to collective
                # summation order.
                probe = spectral_probes.probe_sharded_edge_arrays(
                    self._mesh, store.src, store.dst, store.weight,
                    probe_key, jnp.asarray(n, jnp.int32),
                    num_nodes=store.num_nodes,
                    edge_axes=cfg.edge_axes,
                    num_probes=cfg.probe_vectors,
                    num_steps=cfg.probe_steps,
                    backend=self._backend,
                )
            else:
                probe = spectral_probes.probe_edge_arrays(
                    store.src, store.dst, store.weight, probe_key,
                    jnp.asarray(n, jnp.int32),
                    num_nodes=store.num_nodes,
                    num_probes=cfg.probe_vectors,
                    # NOT clamped to n: probe_steps is jit-static, and
                    # the Lanczos recurrence handles m >= n via sticky
                    # breakdown, so the compile stays shared across the
                    # capacity class.
                    num_steps=cfg.probe_steps,
                    backend=self._backend,
                )
            est = float(probe.lambda_max)
            if np.isfinite(est) and est > 0.0:
                rho = min(est, rho_ub)
                lam_k = spectral_probes.bottom_edge(probe, cfg.k)[0]
        return store, rho, rho_ub, lam_k

    def _set_scale(self, sess: _Session, rho: float, rho_ub: float,
                   lam_k: float | None = None) -> None:
        """Per-session dilation scale c = tau / (rho * degree).

        tau is the configured strength, re-planned down by the spectral
        planner's wanted-decay cap when a probe localized lam_k (a tight
        rho would otherwise DOUBLE the effective strength the constants
        were tuned for, over-dilating tenants whose wanted spread is a
        sizable fraction of rho); floored so dilation never vanishes.
        Without fresh probe information (ordinary update batches) the
        session's last planned tau carries over.
        """
        from repro.spectral.plan import TAU_GRID, wanted_decay_cap

        if lam_k is not None and rho > 0.0:
            tau = self.cfg.dilation_strength
            sess.tau = max(min(tau, wanted_decay_cap(lam_k, rho)),
                           min(tau, TAU_GRID[0]))
        sess.rho = rho
        sess.rho_ub = rho_ub
        sess.c = float(sess.tau / (max(rho, 1e-30) * self.cfg.degree))

    # ------------------------------------------------------------------
    # admission / eviction
    # ------------------------------------------------------------------

    def add_graph(self, sid: str, g, num_clusters: int | None = None,
                  edge_capacity: int | None = None) -> None:
        """Admit a graph into its capacity class, cold-initialized."""
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} already exists")
        cfg = self.cfg
        clusters = num_clusters or cfg.num_clusters
        need = clusters + (1 if cfg.drop_trivial else 0)
        if need > cfg.k:
            raise ValueError(
                f"num_clusters={clusters} needs {need} tracked "
                f"eigenvectors (drop_trivial={cfg.drop_trivial}) but "
                f"ServiceConfig.k={cfg.k}")
        node_cap = node_capacity_class(g.num_nodes)
        cap = (gs.capacity_class(g.num_edges) if edge_capacity is None
               else edge_capacity)
        store = gs.from_edge_list(g, capacity=self._balanced(cap),
                                  num_nodes=node_cap)
        store, rho, rho_ub, lam_k = self._rho_estimate(store, g.num_nodes)
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                 self._admitted)
        self._admitted += 1
        sess = _Session(
            sid=sid,
            n=g.num_nodes,
            num_clusters=clusters,
            store=store,
            v=_init_panel(key, node_cap, g.num_nodes, cfg.k),
            c=0.0,
            rho=rho,
            rho_ub=rho_ub,
            tau=cfg.dilation_strength,
            tracker=tracking.LabelTracker(clusters),
        )
        self._set_scale(sess, rho, rho_ub, lam_k)
        sess.solves = 1  # the admission cold solve
        self._sessions[sid] = sess

    def evict(self, sid: str) -> dict:
        """Remove a session; returns its summary."""
        sess = self._sessions.pop(sid)
        return self._summary(sess)

    def evict_converged(self) -> dict[str, dict]:
        """Drop every converged session (label consumers are done)."""
        done = [s for s in self._sessions.values() if s.converged]
        return {s.sid: self.evict(s.sid) for s in done}

    # ------------------------------------------------------------------
    # streaming updates
    # ------------------------------------------------------------------

    def apply_updates(self, sid: str, edges, weights,
                      mode: str = "set") -> gs.BatchStats:
        """Apply an edge batch; converged sessions take the first-order
        eigen-update path, falling back to a warm re-solve on drift."""
        cfg = self.cfg
        sess = self._sessions[sid]
        pad = max(_next_pow2(len(np.atleast_1d(weights))),
                  cfg.min_batch_pad)
        batch = gs.coalesce_batch(edges, weights, mode=mode, pad_to=pad)
        store, dw, stats = gs.apply_edge_batch(sess.store, batch, mode=mode)
        base = sess.store
        while int(stats.dropped) > 0:
            # buffer overflow: grow the ORIGINAL store (untouched —
            # apply is functional) and re-apply the whole batch, growing
            # again until nothing drops (a batch can exceed one ladder
            # step).  The session changes capacity class, so its next
            # tick joins a different group.  Sharded serving keeps the
            # grown capacity a multiple of the shard count.
            base = gs.grow(base)
            if base.capacity != self._balanced(base.capacity):
                base = gs.grow(base, self._balanced(base.capacity))
            store, dw, stats = gs.apply_edge_batch(base, batch, mode=mode)
        # Ordinary batches rescale cheaply: track the probed estimate by
        # the Gershgorin bound's relative change (no probe matvecs), cap
        # by the fresh bound.  Full re-probes happen on admission and on
        # the drift-triggered re-solve below.
        store, rho_ub = gs.spectral_radius_upper_bound(store)
        rho_ub_new = float(rho_ub)
        sess.store = store
        # edge mutation stales the pallas layouts (single and sharded)
        sess.blocking = None
        sess.sharded_blocking = None
        if sess.rho_ub > 0.0:
            rho_new = min(rho_ub_new,
                          sess.rho * rho_ub_new / sess.rho_ub)
        else:
            # degenerate (edgeless) admission: rho == rho_ub == 0, and
            # the ratio would pin rho at 0 forever (c -> 1/eps -> NaN
            # panels); re-anchor on the fresh bound instead
            rho_new = rho_ub_new
        self._set_scale(sess, rho_new, rho_ub_new)
        if sess.est is not None:
            prev_v = sess.est.v
            est, drift_flag = updates.update_or_flag(
                sess.est, batch.src, batch.dst, dw,
                updates.UpdateConfig(fallback_ratio=cfg.fallback_ratio))
            sess.v = est.v
            sess.incremental_updates += 1
            if not drift_flag:
                sess.est = est  # cheap path: drift bound still safe
                return stats
            # The drift bound is conservative (Σ 2|dw| vs the min
            # PANEL gap, which bulk eigenvalues make tiny) — so before
            # paying for a re-solve, VERIFY with one operator
            # application: does the updated panel still meet tolerance
            # under the new operator?
            res = float(self._residual(sess))
            sess.residual = res
            if res <= 2.0 * cfg.tol:
                # panel survived: re-anchor the estimate (drift resets)
                st = sess.store
                sess.est = _anchor_estimate(st.src, st.dst, st.weight,
                                            sess.v)
                return stats
            # Full SPED re-solve.  The accumulated drift that invalidated
            # the panel also staled the admission-time lambda_max, so
            # RE-PROBE the spectrum and re-anchor the dilation scale
            # before deciding how to seed the solve.  A first-order
            # update outside its validity region can be WORSE than the
            # stale panel, so seed from whichever candidate has the
            # lower residual under the new (re-probed) operator; go cold
            # when even that fails the restart test (stream.warm).
            sess.fallbacks += 1
            sess.est = None
            sess.converged = False
            st2, rho2, rho_ub2, lam_k2 = self._rho_estimate(
                sess.store, sess.n)
            sess.store = st2
            self._set_scale(sess, rho2, rho_ub2, lam_k2)
            res = float(self._residual(sess))  # est.v under re-probed op
            sess.v = prev_v
            res_prev = float(self._residual(sess))
            if res <= res_prev:
                sess.v, best = est.v, res
            else:
                best = res_prev
            if best > cfg.restart_residual:
                key = jax.random.fold_in(
                    jax.random.PRNGKey(cfg.seed + 1), sess.solves)
                sess.v = _init_panel(key, sess.store.num_nodes,
                                     sess.n, cfg.k)
            sess.residual = best
            sess.solves += 1
        return stats

    # ------------------------------------------------------------------
    # batched ticking
    # ------------------------------------------------------------------

    def _class_key(self, sess: _Session) -> tuple[int, int]:
        return (sess.store.num_nodes, sess.store.capacity)

    def _ensure_blocking(self, sess: _Session) -> None:
        """Build (or rebuild after updates) the session's node-blocked
        layout for pallas ticks — host-side, cached on the session.
        Sharded serving builds the per-shard variant instead."""
        if self._mesh is not None:
            if sess.sharded_blocking is None:
                sess.sharded_blocking = gs.sharded_node_blocking(
                    sess.store, self._num_shards,
                    block_n=self.cfg.tick_block_n)
        elif sess.blocking is None:
            sess.blocking = gs.node_blocking(
                sess.store, block_n=self.cfg.tick_block_n)

    def _group_key(self, sess: _Session) -> tuple:
        """Sessions sharing a group share one compiled tick program.

        Segment groups by capacity class; pallas additionally groups by
        the blocking's static layout (block size and pow2-snapped chunk
        count), since those are the shapes the kernel compiles against —
        sharded pallas uses the per-shard layout's statics the same way.
        A converged session whose blocking was invalidated by updates
        keeps its LAST group key — it won't tick, so no layout rebuild,
        but it must keep anchoring its old group's occupancy bucket
        (shrinking buckets would recompile the tick program).
        """
        if self._backend == "pallas":
            cached = (sess.sharded_blocking if self._mesh is not None
                      else sess.blocking)
            if (cached is None and sess.converged
                    and sess.group_key is not None):
                return sess.group_key
            self._ensure_blocking(sess)
            b = (sess.sharded_blocking if self._mesh is not None
                 else sess.blocking)
            key = (self._class_key(sess), b.block_n, b.chunks_per_block,
                   b.block_e)
        else:
            key = (self._class_key(sess),)
        sess.group_key = key
        return key

    def _get_step(self, key: tuple, occupancy: int):
        from repro.stream import sharded as sharded_mod

        fn = self._compiled.get((key, occupancy))
        if fn is None:
            cfg = self.cfg
            if self._mesh is not None and self._backend == "pallas":
                (node_cap, _), block_n, chunks, block_e = key
                fn = sharded_mod.build_tick_program_pallas(
                    self._mesh, cfg.edge_axes, cfg.method, cfg.degree,
                    cfg.steps_per_tick, cfg.lr,
                    block_n, block_e, chunks, node_cap)
            elif self._mesh is not None:
                fn = sharded_mod.build_tick_program_segment(
                    self._mesh, cfg.edge_axes, cfg.method, cfg.degree,
                    cfg.steps_per_tick, cfg.lr)
            elif self._backend == "pallas":
                _, block_n, chunks, block_e = key
                fn = self._build_step_pallas(block_n, chunks, block_e)
            else:
                fn = self._build_step()
            self._compiled[(key, occupancy)] = fn
        return fn

    @property
    def compile_count(self) -> int:
        """Distinct compiled tick programs (capacity class × occupancy
        bucket) — the no-per-session-recompilation invariant's witness."""
        return len(self._compiled)

    def _build_step(self):
        cfg = self.cfg
        step_fn = solvers.STEP_FNS[cfg.method]

        def one(src, dst, w, v, c):
            def opv(u):
                def body(_, x):
                    return x - c * _edge_mv(src, dst, w, x)
                return jax.lax.fori_loop(0, cfg.degree, body, u)

            state = solvers.SolverState(v=v, step=jnp.zeros((), jnp.int32))

            def sstep(st, _):
                return step_fn(st, opv(st.v), cfg.lr), None

            state, _ = jax.lax.scan(
                sstep, state, None, length=cfg.steps_per_tick)
            av = opv(state.v)
            return state.v, metrics.panel_residual(state.v, av)

        return jax.jit(jax.vmap(one))

    def _build_step_pallas(self, block_n: int, chunks: int, block_e: int):
        """Tick program on the pallas backend: the per-session operator
        (I - c L)^degree runs the node-blocked incidence-SpMM kernel
        with the dilation step (alpha=-c, beta=1) fused into its
        epilogue, and the solver step uses the fused mu-EG kernel.

        Sessions are advanced with ``lax.map`` over the group's stacked
        blocking arrays — pallas grids don't vmap across the session
        axis, so the batching win here is per-matvec MXU utilization,
        not cross-session fusion; the program is still compiled ONCE per
        (class, blocking layout, occupancy bucket).
        """
        cfg = self.cfg
        interp = backend_mod.kernel_interpret()
        step_fn = solvers.make_step_fn(cfg.method, self._backend)

        def one(args):
            u_local, other, w, deg, v, c = args
            nb = es_ops.NodeBlocking(
                u_local=u_local, other=other, weight=w, deg=deg,
                block_n=block_n, block_e=block_e,
                chunks_per_block=chunks, num_nodes=v.shape[0])

            def opv(u):
                def body(_, x):
                    return es_ops.edge_spmm_blocked(
                        nb, x, alpha=-c, beta=1.0, interpret=interp)
                return jax.lax.fori_loop(0, cfg.degree, body, u)

            state = solvers.SolverState(v=v, step=jnp.zeros((), jnp.int32))

            def sstep(st, _):
                return step_fn(st, opv(st.v), cfg.lr), None

            state, _ = jax.lax.scan(
                sstep, state, None, length=cfg.steps_per_tick)
            av = opv(state.v)
            return state.v, metrics.panel_residual(state.v, av)

        return jax.jit(lambda args: jax.lax.map(one, args))

    def tick(self) -> dict[str, float]:
        """Advance every unconverged session cfg.steps_per_tick solver
        steps — one compiled program invocation per capacity class (and,
        on pallas, per blocking layout)."""
        cfg = self.cfg
        groups: dict[tuple, list[_Session]] = defaultdict(list)
        totals: dict[tuple, int] = defaultdict(int)
        for sess in self._sessions.values():
            # totals count converged sessions too, PER GROUP: a group's
            # occupancy must not shrink as its members converge, but it
            # also must not pad to the whole class's total when pallas
            # splits a class across blocking layouts (_group_key reuses
            # a converged session's last key rather than rebuilding its
            # invalidated blocking)
            totals[self._group_key(sess)] += 1
        for sess in self._sessions.values():
            if not sess.converged:
                groups[self._group_key(sess)].append(sess)
        out: dict[str, float] = {}
        for gkey, members in groups.items():
            # occupancy bucket follows the group's TOTAL session count,
            # not the active count, so sessions converging one by one
            # never shrink the bucket (stable shapes => zero recompiles
            # until the user actually evicts)
            occ = _next_pow2(totals[gkey])
            step = self._get_step(gkey, occ)
            idx = list(range(len(members))) + [0] * (occ - len(members))
            stack = lambda f: jnp.stack([f(members[i]) for i in idx])
            cs = jnp.asarray([members[i].c for i in idx], jnp.float32)
            if self._mesh is not None and self._backend == "pallas":
                from repro.stream import sharded as sharded_mod

                vs, res = step(*sharded_mod.tick_group_arrays_pallas(
                    [members[i] for i in idx]))
            elif self._backend == "pallas" and self._mesh is None:
                vs, res = step((
                    stack(lambda s: s.blocking.u_local),
                    stack(lambda s: s.blocking.other),
                    stack(lambda s: s.blocking.weight),
                    stack(lambda s: s.blocking.deg),
                    stack(lambda s: s.v),
                    cs,
                ))
            else:
                # single-device segment AND sharded segment take the
                # same stacked-edge-buffer signature (stream.sharded
                # shards the capacity axis over the mesh)
                vs, res = step(
                    stack(lambda s: s.store.src),
                    stack(lambda s: s.store.dst),
                    stack(lambda s: s.store.weight),
                    stack(lambda s: s.v),
                    cs,
                )
            res = np.asarray(res)
            for i, sess in enumerate(members):
                sess.v = vs[i]
                sess.residual = float(res[i])
                sess.ticks += 1
                out[sess.sid] = sess.residual
                if sess.residual <= cfg.tol:
                    sess.converged = True
                    st = sess.store
                    sess.est = _anchor_estimate(st.src, st.dst, st.weight,
                                                sess.v)
        return out

    @property
    def all_converged(self) -> bool:
        return all(s.converged for s in self._sessions.values())

    def run_until_converged(self, max_ticks: int = 500) -> int:
        """Tick until every session converges; returns ticks used.

        Check `all_converged` afterwards: hitting the tick budget without
        converging also returns (with the budget spent), and serving
        labels from an unconverged panel is the caller's decision.
        """
        used = 0
        while not self.all_converged and used < max_ticks:
            self.tick()
            used += 1
        return used

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def _residual(self, sess: _Session) -> float:
        st = sess.store
        return float(_op_residual(st.src, st.dst, st.weight, sess.v,
                                  sess.c, self.cfg.degree))

    def live_edges(self, sid: str) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, weight) of the session's live edges — the public
        view of the store for consumers building update batches."""
        st = self._sessions[sid].store
        w = np.asarray(st.weight)
        live = w != 0
        return np.asarray(st.src)[live], np.asarray(st.dst)[live], w[live]

    def labels(self, sid: str) -> np.ndarray:
        """Current cluster assignment with STABLE ids (tracking.py)."""
        cfg = self.cfg
        sess = self._sessions[sid]
        start = 1 if cfg.drop_trivial else 0
        emb = sess.v[: sess.n, start: start + sess.num_clusters]
        norms = jnp.linalg.norm(emb, axis=1, keepdims=True)
        emb = emb / jnp.maximum(norms, 1e-12)
        res = km.kmeans(
            jax.random.PRNGKey(cfg.seed + 2), emb, sess.num_clusters,
            restarts=cfg.kmeans_restarts)
        return np.asarray(sess.tracker.update(res.labels))

    def session_info(self, sid: str) -> dict:
        return self._summary(self._sessions[sid])

    @staticmethod
    def _summary(sess: _Session) -> dict:
        return {
            "n": sess.n,
            "node_capacity": sess.store.num_nodes,
            "edge_capacity": sess.store.capacity,
            "num_edges": int(gs.num_edges(sess.store)),
            "converged": sess.converged,
            "residual": sess.residual,
            "rho": sess.rho,
            "rho_ub": sess.rho_ub,
            "tau": sess.tau,
            "ticks": sess.ticks,
            "solves": sess.solves,
            "incremental_updates": sess.incremental_updates,
            "fallbacks": sess.fallbacks,
        }
