"""Sharded serving: mesh-parallel capacity-class ticks.

``ServiceConfig(mesh=...)`` routes every capacity-class tick through ONE
shard_mapped fused series program (built here, compiled once per
(capacity class, blocking layout, occupancy bucket) exactly like the
single-device tick programs): the group's stacked edge buffers (segment
backend) or stacked per-shard node blockings (pallas backend) are
partitioned over the mesh's edge axes, each dilation matvec runs the
per-shard kernel and then ONE psum of the whole group's stacked
(G, n, k) panels — the paper's "polynomial matvecs distribute
trivially" claim, made concrete — and the solver step plus the panel
residual run replicated on the psum'd panels.

Decomposition contract (see ``kernels.edge_spmm.ops
.ShardedNodeBlocking``): shard ``s`` computes ``deg_s * v - A_s v``
from ITS contiguous slice of the capacity-padded edge buffer only, so
the psum reconstructs ``L v`` with no double-counted diagonal; a shard
whose slice is all capacity padding contributes exact zeros.  The
streaming store's capacity classes keep the slices balanced: admission
and growth round edge capacities up to a multiple of the shard count
(``balanced_capacity``), so every shard owns ``capacity / S`` slots.

The kernel-epilogue AXPY fusion of the single-device pallas tick is a
within-device luxury: sharded, the psum is the fusion barrier, so the
dilation step ``u - c * L u`` applies post-psum (bitwise identical to
the segment recurrence ordering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import backend as backend_mod
from repro.core import laplacian as lap
from repro.core import metrics, solvers
from repro.core.distributed import num_edge_shards
from repro.kernels.edge_spmm import ops as es_ops


def balanced_capacity(capacity: int, num_shards: int) -> int:
    """Smallest capacity >= `capacity` dividing evenly into the shards.

    Capacity classes are powers of two and meshes are usually too, so
    this is almost always the identity — it exists for odd-shaped
    meshes, and to make the balance invariant explicit at the two call
    sites (admission, growth) instead of implicit in the ladder.
    """
    return capacity + (-capacity) % max(num_shards, 1)


def build_tick_program_segment(mesh, edge_axes, method: str, degree: int,
                               steps_per_tick: int, lr: float):
    """Sharded segment tick: fn(src, dst, w, vs, cs) -> (vs', residuals).

    Inputs are the group's stacked (G, cap) edge buffers — sharded over
    ``edge_axes`` along the capacity axis — and replicated (G, n, k)
    panels / (G,) dilation scales.  The per-shard gather/scatter matvec
    is vmapped over sessions, so each dilation step costs ONE psum of
    the stacked (G, n, k) panels for the whole group.
    """
    step_fn = solvers.STEP_FNS[method]
    spec_e = P(None, edge_axes)  # (G, cap): shard the capacity axis

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, P(), P()),
        out_specs=(P(), P()),
        check_vma=False)  # scan carries mix varying/unvarying values
    def tick(src, dst, w, vs, cs):
        local_mv = jax.vmap(lap.edge_matvec_arrays)

        def opv(us):  # (G, n, k) -> (G, n, k), one psum per dilation step
            def body(_, xs):
                lxs = jax.lax.psum(local_mv(src, dst, w, xs), edge_axes)
                return xs - cs[:, None, None] * lxs
            return jax.lax.fori_loop(0, degree, body, us)

        state = solvers.SolverState(
            v=vs, step=jnp.zeros((vs.shape[0],), jnp.int32))

        def sstep(st, _):
            avs = opv(st.v)
            return jax.vmap(step_fn, in_axes=(0, 0, None))(st, avs, lr), None

        state, _ = jax.lax.scan(sstep, state, None, length=steps_per_tick)
        avs = opv(state.v)
        return state.v, jax.vmap(metrics.panel_residual)(state.v, avs)

    return jax.jit(tick)


def build_tick_program_pallas(mesh, edge_axes, method: str, degree: int,
                              steps_per_tick: int, lr: float,
                              block_n: int, block_e: int, chunks: int,
                              num_nodes: int):
    """Sharded pallas tick: per-shard NODE-BLOCKED kernels + one psum.

    fn(u_local, other, w, deg, vs, cs) -> (vs', residuals), where the
    blocking arrays are the group's stacked per-shard layouts of shape
    (G, S, NB*C*BE) — sharded over ``edge_axes`` along the shard axis —
    and deg is (G, S, NB*block_n) PER-SHARD degrees.  Pallas grids don't
    vmap across the session axis, so the kernel (and the fused mu-EG
    step) advance sessions under ``lax.map``; every device runs the same
    map length, so the per-matvec psum stays collective-matched.  Panels
    of any n tick this way — the sharded path scales past
    ``ONE_HOT_NODE_LIMIT`` with only (block_n, k) slices in VMEM.
    """
    interp = backend_mod.kernel_interpret()
    step_fn = solvers.make_step_fn(method, "pallas")
    static = dict(block_n=block_n, block_e=block_e,
                  chunks_per_block=chunks, num_nodes=num_nodes)
    spec_b = P(None, edge_axes)  # (G, S, L): shard the shard axis

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_b, spec_b, spec_b, spec_b, P(), P()),
        out_specs=(P(), P()),
        check_vma=False)  # pallas_call has no replication rule
    def tick(u_local, other, w, deg, vs, cs):
        def local_mv(xs):  # (G, n, k) -> per-shard (deg_s*x - A_s x)
            def one(args):
                ul, ot, wt, dg, x = args
                local = es_ops.shard_local_blocking(ul, ot, wt, dg,
                                                    **static)
                return es_ops.edge_spmm_blocked(local, x, interpret=interp)
            return jax.lax.map(one, (u_local, other, w, deg, xs))

        def opv(us):
            def body(_, xs):
                lxs = jax.lax.psum(local_mv(xs), edge_axes)
                return xs - cs[:, None, None] * lxs
            return jax.lax.fori_loop(0, degree, body, us)

        state = solvers.SolverState(
            v=vs, step=jnp.zeros((vs.shape[0],), jnp.int32))

        def sstep(st, _):
            avs = opv(st.v)
            new = jax.lax.map(
                lambda args: step_fn(
                    solvers.SolverState(v=args[0], step=args[1]),
                    args[2], lr),
                (st.v, st.step, avs))
            return new, None

        state, _ = jax.lax.scan(sstep, state, None, length=steps_per_tick)
        avs = opv(state.v)
        return state.v, jax.vmap(metrics.panel_residual)(state.v, avs)

    return jax.jit(tick)


def tick_group_arrays_pallas(sessions):
    """Stack a tick group's per-session sharded blockings + panels into
    the (G, S, ...) inputs of :func:`build_tick_program_pallas`."""
    return (
        jnp.stack([s.sharded_blocking.u_local for s in sessions]),
        jnp.stack([s.sharded_blocking.other for s in sessions]),
        jnp.stack([s.sharded_blocking.weight for s in sessions]),
        jnp.stack([s.sharded_blocking.deg for s in sessions]),
        jnp.stack([s.v for s in sessions]),
        jnp.asarray([s.c for s in sessions], jnp.float32),
    )


__all__ = [
    "balanced_capacity",
    "build_tick_program_pallas",
    "build_tick_program_segment",
    "num_edge_shards",
    "tick_group_arrays_pallas",
]
