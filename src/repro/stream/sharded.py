"""Sharded serving: mesh-parallel capacity-class ticks.

``ServiceConfig(mesh=...)`` routes every session-group tick through ONE
shard_mapped program compiled once per (capacity class, degree, blocking
layout, occupancy bucket, steps multiplier) exactly like the
single-device tick programs.  The tick programs themselves live in
:mod:`repro.core.program` (``build_tick_sharded_segment`` /
``build_tick_sharded_pallas``) — the same unified solve loop as the
one-shot and single-device paths; this module keeps the mesh POLICY the
streaming store must uphold:

Decomposition contract (see ``kernels.edge_spmm.ops
.ShardedNodeBlocking``): shard ``s`` computes ``deg_s * v - A_s v``
from ITS contiguous slice of the capacity-padded edge buffer only, so
the psum reconstructs ``L v`` with no double-counted diagonal; a shard
whose slice is all capacity padding contributes exact zeros.  The
streaming store's capacity classes keep the slices balanced: admission
and growth round edge capacities up to a multiple of the shard count
(``balanced_capacity``), so every shard owns ``capacity / S`` slots.

The kernel-epilogue AXPY fusion of the single-device pallas tick is a
within-device luxury: edge-sharded, the psum is the fusion barrier, so
the dilation step ``u - c * L u`` applies post-psum (bitwise identical
to the segment recurrence ordering).

PANEL sharding (``ServiceConfig(model_axes=...)``) is the second mesh
policy: the (n, k) panel itself splits by row range — shard ``s`` owns
rows ``[s * R, (s + 1) * R)`` and the destination-aligned half-edge
layout landing there (``graph_store.model_sharded_blocking``) — so its
local matvec rows are FINAL (the AXPY fuses back into the kernel
epilogue), collectives merely assemble disjoint rows, and mu-EG steps
ship their row assembly + 2k x 2k gram in ONE fused psum
(``build_tick_model_sharded``).  There is no edge-balance contract to
uphold: the layout re-buckets edges by destination itself, so any
capacity works on any shard count.
"""
from __future__ import annotations

from repro.core.distributed import num_edge_shards
from repro.core.program import (  # noqa: F401  (re-exported tick builders)
    build_tick_model_sharded,
    build_tick_sharded_pallas,
    build_tick_sharded_segment,
    num_model_shards,
)


def balanced_capacity(capacity: int, num_shards: int) -> int:
    """Smallest capacity >= `capacity` dividing evenly into the shards.

    Capacity classes are powers of two and meshes are usually too, so
    this is almost always the identity — it exists for odd-shaped
    meshes, and to make the balance invariant explicit at the two call
    sites (admission, growth) instead of implicit in the ladder.
    """
    return capacity + (-capacity) % max(num_shards, 1)


__all__ = [
    "balanced_capacity",
    "build_tick_model_sharded",
    "build_tick_sharded_pallas",
    "build_tick_sharded_segment",
    "num_edge_shards",
    "num_model_shards",
]
