"""Sharded serving: mesh-parallel capacity-class ticks.

``ServiceConfig(mesh=...)`` routes every session-group tick through ONE
shard_mapped program compiled once per (capacity class, degree, blocking
layout, occupancy bucket, steps multiplier) exactly like the
single-device tick programs.  The tick programs themselves live in
:mod:`repro.core.program` (``build_tick_sharded_segment`` /
``build_tick_sharded_pallas``) — the same unified solve loop as the
one-shot and single-device paths; this module keeps the mesh POLICY the
streaming store must uphold:

Decomposition contract (see ``kernels.edge_spmm.ops
.ShardedNodeBlocking``): shard ``s`` computes ``deg_s * v - A_s v``
from ITS contiguous slice of the capacity-padded edge buffer only, so
the psum reconstructs ``L v`` with no double-counted diagonal; a shard
whose slice is all capacity padding contributes exact zeros.  The
streaming store's capacity classes keep the slices balanced: admission
and growth round edge capacities up to a multiple of the shard count
(``balanced_capacity``), so every shard owns ``capacity / S`` slots.

The kernel-epilogue AXPY fusion of the single-device pallas tick is a
within-device luxury: sharded, the psum is the fusion barrier, so the
dilation step ``u - c * L u`` applies post-psum (bitwise identical to
the segment recurrence ordering).
"""
from __future__ import annotations

from repro.core.distributed import num_edge_shards
from repro.core.program import (  # noqa: F401  (re-exported tick builders)
    build_tick_sharded_pallas,
    build_tick_sharded_segment,
)


def balanced_capacity(capacity: int, num_shards: int) -> int:
    """Smallest capacity >= `capacity` dividing evenly into the shards.

    Capacity classes are powers of two and meshes are usually too, so
    this is almost always the identity — it exists for odd-shaped
    meshes, and to make the balance invariant explicit at the two call
    sites (admission, growth) instead of implicit in the ladder.
    """
    return capacity + (-capacity) % max(num_shards, 1)


__all__ = [
    "balanced_capacity",
    "build_tick_sharded_pallas",
    "build_tick_sharded_segment",
    "num_edge_shards",
]
