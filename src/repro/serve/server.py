"""Request-level serving over :class:`~repro.stream.service.StreamingService`.

``Server`` is the in-process front end (the HTTP shell in
:mod:`repro.serve.http` is a thin adapter over it) exposing the five
request types — **admit** a graph, **push** an edge batch, query
**labels**, query a session **summary**, **evict** — with the process
concerns the library never had:

* **async ingest / tick pipeline** (``pipeline="double_buffer"``, the
  default): pushes DO NOT touch the solve engine.  Each push merges its
  edges into a host-side staging buffer (mode-aware last-write-wins /
  accumulate semantics per edge key, so N pushes against one session
  flush as one coalesced ``apply_edge_batch`` instead of N) and returns
  immediately.  A dedicated engine thread swaps the double buffer each
  iteration — ingest keeps filling the fresh front buffer while the
  engine drains the back buffer and runs the scheduled device tick —
  so ingest and ticking no longer serialize.
  ``pipeline="serialized"`` is the pre-pipeline baseline (each push
  applies inline under the engine lock, contending with device ticks);
  it exists for the A/B comparison in ``benchmarks/bench_serve.py``.
* **versioned reads**: ``labels``/``summary`` are served from the last
  committed :class:`~repro.serve.results.VersionedResults` version —
  monotonic version ids, stable cluster ids (the store's own
  per-session tracker), and NO engine lock on the query path, so a
  slow device tick never stalls a read.
* **observability**: per-request-type latency histograms (p50/p99 via
  :mod:`repro.serve.metrics`), pipeline counters (staged / applied /
  dropped batches, commits, ticks), and queue-depth / tick-utilization
  gauges, all surfaced by :meth:`Server.stats`.

Thread model: ONE engine thread owns every ``StreamingService`` call
(the engine lock exists only because ``admit``/``evict``/serialized
pushes run on request threads); any number of request threads stage
pushes and read results concurrently.  Unknown or evicted session ids
raise :class:`~repro.stream.service.UnknownSessionError` end to end —
the HTTP layer maps it to 404.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core import laplacian as lap
from repro.serve.metrics import ServeMetrics
from repro.serve.results import VersionedResults
from repro.stream.service import (
    ServiceConfig,
    StreamingService,
    UnknownSessionError,
    panel_labels,
)

REQUEST_OPS = ("admit", "push", "labels", "summary", "evict")
PIPELINES = ("double_buffer", "serialized")


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    service: ServiceConfig = ServiceConfig()
    pipeline: str = "double_buffer"  # | "serialized" (A/B baseline)
    idle_sleep_s: float = 0.002  # engine-thread wait when nothing to do
    drop_evicted_results: bool = False  # True = free memory eagerly

    def __post_init__(self):
        if self.pipeline not in PIPELINES:
            raise ValueError(f"unknown pipeline {self.pipeline!r}")


class _PendingBuffer:
    """Host-side accumulation of staged edge updates for one session.

    Merge semantics reproduce sequential application order per edge key
    (keys are canonicalized (min, max) pairs, matching the store):
    ``set`` overwrites whatever is pending, ``add`` accumulates onto a
    pending value of either mode.  Flushing yields at most one batch
    per mode, so a burst of pushes costs one ``apply_edge_batch`` each.
    """

    __slots__ = ("slots", "batches_staged")

    def __init__(self):
        self.slots: dict[tuple[int, int], list] = {}
        self.batches_staged = 0

    def merge(self, edges: np.ndarray, weights: np.ndarray,
              mode: str) -> int:
        self.batches_staged += 1
        slots = self.slots
        for (a, b), w in zip(edges, weights):
            key = (int(a), int(b)) if a <= b else (int(b), int(a))
            slot = slots.get(key)
            if mode == "set" or slot is None:
                slots[key] = [mode, float(w)]
            else:
                slot[1] += float(w)
        return len(edges)

    def flush_batches(self):
        """Yield (edges, weights, mode) — one coalesced batch per mode."""
        by_mode: dict[str, tuple[list, list]] = {}
        for (a, b), (mode, w) in self.slots.items():
            pairs, ws = by_mode.setdefault(mode, ([], []))
            pairs.append((a, b))
            ws.append(w)
        for mode, (pairs, ws) in by_mode.items():
            yield (np.asarray(pairs, np.int64),
                   np.asarray(ws, np.float32), mode)


class Server:
    """In-process serving front end; see the module docstring."""

    def __init__(self, cfg: ServerConfig = ServerConfig()):
        self.cfg = cfg
        self.service = StreamingService(cfg.service)
        self.results = VersionedResults()
        self.metrics = ServeMetrics(REQUEST_OPS)
        self._engine_lock = threading.RLock()
        self._stage_lock = threading.Lock()
        self._front: dict[str, _PendingBuffer] = {}
        self._known: set[str] = set()
        self._labelers: dict[str, object] = {}
        self._wake = threading.Event()
        self._drain_cond = threading.Condition()
        self._drained_seq = 0
        self._tick_busy_s = 0.0
        self._t0 = time.perf_counter()
        self._stop_flag = False
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------

    def admit(self, sid: str, edges, num_nodes: int, weights=None,
              num_clusters: int | None = None,
              edge_capacity: int | None = None,
              resume_panel=None) -> dict:
        """Admit a graph; commits result version 1 immediately, so
        labels/summary are queryable before the first tick lands."""
        with self.metrics.timed("admit"):
            edges = np.asarray(edges, np.int64).reshape(-1, 2)
            g = lap.make_edge_list(edges, int(num_nodes), weights=weights)
            svc_cfg = self.cfg.service
            clusters = num_clusters or svc_cfg.num_clusters
            with self._engine_lock:
                self.service.add_graph(
                    sid, g, num_clusters=num_clusters,
                    edge_capacity=edge_capacity,
                    resume_panel=resume_panel)
                self.results.register(sid, clusters)
                version = self._commit(sid)
            labeler = lambda panel: panel_labels(
                panel, clusters, drop_trivial=svc_cfg.drop_trivial,
                seed=svc_cfg.seed,
                kmeans_restarts=svc_cfg.kmeans_restarts)
            with self._stage_lock:
                self._known.add(sid)
                self._labelers[sid] = labeler
            self.metrics.inc("admitted")
            self._wake.set()
            summary = self.summary_unmetered(sid)
            summary["version"] = version
            return summary

    def push(self, sid: str, edges, weights, mode: str = "set") -> dict:
        """Stage (or, serialized pipeline, apply) one edge batch."""
        with self.metrics.timed("push"):
            if mode not in ("set", "add"):
                raise ValueError(f"unknown update mode {mode!r}")
            edges = np.asarray(edges, np.int64).reshape(-1, 2)
            weights = np.atleast_1d(np.asarray(weights, np.float32))
            if len(weights) != len(edges):
                raise ValueError(
                    f"{len(edges)} edges but {len(weights)} weights")
            if self.cfg.pipeline == "serialized":
                with self._engine_lock:
                    stats = self.service.apply_updates(
                        sid, edges, weights, mode=mode)
                    version = self._commit(sid)
                self.metrics.inc("applied_batches")
                return {"staged": 0, "applied": int(len(edges)),
                        "matched": int(stats.matched),
                        "version": version, "queue_depth": 0}
            with self._stage_lock:
                if sid not in self._known:
                    raise UnknownSessionError(sid)
                buf = self._front.setdefault(sid, _PendingBuffer())
                n = buf.merge(edges, weights, mode)
                depth = sum(len(b.slots) for b in self._front.values())
            self.metrics.inc("staged_batches")
            self.metrics.set_gauge("queue_depth", depth)
            self._wake.set()
            return {"staged": n, "applied": 0,
                    "version": self.results.version(sid),
                    "queue_depth": depth}

    def labels(self, sid: str) -> dict:
        """Stable-id cluster assignment of the last committed version.

        Served entirely from the versioned results store: no engine
        lock, and repeated queries at one version are cached."""
        with self.metrics.timed("labels"):
            with self._stage_lock:
                labeler = self._labelers.get(sid)
            if labeler is None:
                raise UnknownSessionError(sid)
            lab, version, churn = self.results.labels(sid, labeler)
            return {"sid": sid, "version": version, "churn": churn,
                    "labels": lab}

    def summary(self, sid: str) -> dict:
        """Last committed session summary (carries its version)."""
        with self.metrics.timed("summary"):
            return self.summary_unmetered(sid)

    def summary_unmetered(self, sid: str) -> dict:
        out = self.results.summary(sid)
        out["sid"] = sid
        return out

    def evict(self, sid: str) -> dict:
        """Remove a session; staged-but-undrained batches are dropped
        (counted in ``dropped_batches``).  The returned summary carries
        the live panel for ``admit(resume_panel=...)`` re-admission."""
        with self.metrics.timed("evict"):
            with self._stage_lock:
                self._known.discard(sid)
                self._labelers.pop(sid, None)
                pending = self._front.pop(sid, None)
            if pending is not None:
                self.metrics.inc("dropped_batches",
                                 pending.batches_staged)
            with self._engine_lock:
                summary = self.service.evict(sid)
            self.results.evict(sid, drop=self.cfg.drop_evicted_results)
            self.metrics.inc("evicted")
            return summary

    def stats(self) -> dict:
        """Observability snapshot: latency histograms, pipeline
        counters/gauges, engine and results-store state."""
        snap = self.metrics.snapshot()
        uptime = max(time.perf_counter() - self._t0, 1e-9)
        snap["gauges"]["tick_utilization"] = self._tick_busy_s / uptime
        snap["results"] = self.results.stats()
        with self._engine_lock:
            svc = self.service
            snap["engine"] = {
                "sessions": len(svc.session_ids()),
                "all_converged": svc.all_converged,
                "compile_count": svc.compile_count,
                "tick_invocations": svc.tick_invocations,
                "device_work": svc.device_work,
                "multiplied_ticks": svc.multiplied_ticks,
            }
        return snap

    # ------------------------------------------------------------------
    # the ingest/tick pipeline
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """One engine iteration: swap the double buffer, apply the
        drained batches, run one scheduled tick, commit touched
        versions.  Returns True when any work happened.  The background
        thread calls this in a loop; tests may drive it manually on an
        un-started server."""
        with self._stage_lock:
            staged, self._front = self._front, {}
        touched = []
        drained = 0
        with self._engine_lock:
            # Per-capacity-class drain batching: staged sessions group
            # by their (node, edge) capacity class, and each class pins
            # ONE batch pad (pow2 of its largest coalesced batch) for
            # every member's apply.  The compiled edge-batch apply keys
            # on (capacity class, pad, mode), so the whole class drains
            # through one compiled apply per mode instead of one per
            # pow2 batch size per session.
            classes: dict[tuple, list] = {}
            for sid, buf in staged.items():
                try:
                    ck = self.service.capacity_class(sid)
                except UnknownSessionError:
                    self.metrics.inc("dropped_batches",
                                     buf.batches_staged)
                    continue
                classes.setdefault(ck, []).append(
                    (sid, buf, list(buf.flush_batches())))
            if classes:
                self.metrics.inc("drain_classes", len(classes))
            for members in classes.values():
                pad = max((len(edges) for _, _, batches in members
                           for edges, _, _ in batches), default=0)
                for sid, buf, batches in members:
                    try:
                        for edges, ws, mode in batches:
                            self.service.apply_updates(
                                sid, edges, ws, mode=mode, pad_to=pad)
                        touched.append(sid)
                        drained += buf.batches_staged
                        self.metrics.inc("applied_batches",
                                         buf.batches_staged)
                    except UnknownSessionError:
                        self.metrics.inc("dropped_batches",
                                         buf.batches_staged)
            ticked = {}
            if self.service.session_ids() and not self.service.all_converged:
                t0 = time.perf_counter()
                ticked = self.service.tick()
                self._tick_busy_s += time.perf_counter() - t0
                self.metrics.inc("ticks")
            for sid in {*touched, *ticked}:
                try:
                    self._commit(sid)
                except UnknownSessionError:
                    pass  # raced an eviction; tombstone already served
        if staged:
            with self._stage_lock:
                depth = sum(len(b.slots) for b in self._front.values())
            self.metrics.set_gauge("queue_depth", depth)
        with self._drain_cond:
            self._drained_seq += 1
            self._drain_cond.notify_all()
        return bool(drained or ticked)

    def _commit(self, sid: str) -> int:
        """Version commit point — caller holds the engine lock."""
        summary = self.service.session_info(sid)
        version = self.results.commit(sid, summary,
                                      self.service.panel(sid))
        self.metrics.inc("commits")
        return version

    def _serve_loop(self) -> None:
        while not self._stop_flag:
            if not self.step():
                self._wake.wait(timeout=self.cfg.idle_sleep_s)
                self._wake.clear()
        self.step()  # final drain: stop() loses no staged update

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "Server":
        if self.running:
            raise RuntimeError("server already started")
        self._stop_flag = False
        self._thread = threading.Thread(
            target=self._serve_loop, name="serve-engine", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        """Stop the engine thread after a final drain (clean shutdown:
        every staged batch is applied or counted dropped)."""
        if self._thread is None:
            return
        self._stop_flag = True
        self._wake.set()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError("engine thread did not stop in time")
        self._thread = None

    def __enter__(self) -> "Server":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def flush(self, timeout: float = 60.0) -> bool:
        """Block until every batch staged before the call has been
        drained (applied or dropped).  Returns False on timeout."""
        if not self.running:
            self.step()
            return True
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._stage_lock:
                pending = bool(self._front)
            with self._drain_cond:
                seq = self._drained_seq
            self._wake.set()
            with self._drain_cond:
                ok = self._drain_cond.wait_for(
                    lambda: self._drained_seq > seq,
                    timeout=max(deadline - time.monotonic(), 0.0))
            if not pending and ok:
                # an empty front buffer followed by one full step
                # boundary: any in-flight drain has landed
                with self._stage_lock:
                    if not self._front:
                        return True
        return False

    def wait_converged(self, timeout: float = 120.0) -> bool:
        """Block until staged work is drained AND every session's panel
        is at tolerance (the bench's equal-residual-target barrier)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if not self.flush(timeout=max(deadline - time.monotonic(),
                                          0.0)):
                return False
            with self._engine_lock:
                done = self.service.all_converged
            with self._stage_lock:
                pending = bool(self._front)
            if done and not pending:
                return True
            if not self.running:
                self.step()
            else:
                time.sleep(0.005)
        return False


__all__ = ["PIPELINES", "REQUEST_OPS", "Server", "ServerConfig"]
