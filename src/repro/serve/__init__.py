"""Request-level serving layer over the streaming engine.

- server.py  — ``Server``: admit/push/labels/summary/evict API with the
  double-buffered async ingest/tick pipeline (and the ``serialized``
  A/B baseline).
- results.py — ``VersionedResults``: monotonic result versions, stable
  cluster ids, lazy label materialization; reads never touch the engine.
- metrics.py — ``ServeMetrics``: per-request latency histograms
  (p50/p99), pipeline counters, gauges.
- http.py    — ``ServeHTTP``: stdlib JSON-over-HTTP front end
  (``UnknownSessionError`` -> 404, ``ValueError`` -> 400).
- __main__.py — ``python -m repro.serve`` process shell with clean
  SIGTERM shutdown.
"""
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.serve.results import ResultVersion, VersionedResults
from repro.serve.server import Server, ServerConfig

__all__ = [
    "LatencyHistogram",
    "ResultVersion",
    "ServeMetrics",
    "Server",
    "ServerConfig",
    "VersionedResults",
]
