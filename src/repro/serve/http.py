"""Thin stdlib HTTP front end over :class:`repro.serve.server.Server`.

JSON over ``http.server.ThreadingHTTPServer`` — no web framework, so
the serving layer stays import-clean in the baked container.  Routes:

====== ==================================  =================================
GET    /healthz                            liveness probe
GET    /metrics                            ``Server.stats()`` snapshot
POST   /v1/sessions/{sid}                  admit a graph
POST   /v1/sessions/{sid}/edges            push an edge batch
GET    /v1/sessions/{sid}/labels           stable-id cluster assignment
GET    /v1/sessions/{sid}                  last committed session summary
DELETE /v1/sessions/{sid}                  evict
====== ==================================  =================================

Error mapping is the typed-error satellite made visible on the wire:
:class:`~repro.stream.service.UnknownSessionError` -> **404**,
``ValueError`` (malformed batch / bad mode / duplicate admit) -> **400**,
anything else -> **500** with the exception text in the JSON body.

Request threads are the ThreadingHTTPServer pool; they only ever stage
pushes and read the versioned results store, so the engine thread keeps
exclusive ownership of device work.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.serve.server import Server
from repro.stream.service import UnknownSessionError


def _jsonable(obj):
    """Recursively coerce numpy scalars/arrays into JSON-native types."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if hasattr(obj, "tolist") and not isinstance(obj, (str, bytes)):
        return obj.tolist()  # jax arrays, without importing jax here
    return obj


class _Handler(BaseHTTPRequestHandler):
    # the bound Server instance; set by make_http_server on the subclass
    server_obj: Server = None
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):  # keep stdout for the shell banner
        pass

    # -- plumbing ------------------------------------------------------

    def _reply(self, code: int, payload: dict) -> None:
        body = json.dumps(_jsonable(payload)).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        if length == 0:
            return {}
        data = json.loads(self.rfile.read(length).decode())
        if not isinstance(data, dict):
            raise ValueError("request body must be a JSON object")
        return data

    def _dispatch(self, method: str) -> None:
        try:
            handled = self._route(method)
        except UnknownSessionError as e:
            self._reply(404, {"error": str(e)})
            return
        except (ValueError, json.JSONDecodeError) as e:
            self._reply(400, {"error": str(e)})
            return
        except Exception as e:  # surface, don't kill the worker thread
            self._reply(500, {"error": f"{type(e).__name__}: {e}"})
            return
        if not handled:
            self._reply(404, {"error": f"no route {method} {self.path}"})

    def _route(self, method: str) -> bool:
        srv = self.server_obj
        path = self.path.split("?", 1)[0].rstrip("/")
        if method == "GET" and path == "/healthz":
            self._reply(200, {"ok": True, "running": srv.running})
            return True
        if method == "GET" and path == "/metrics":
            self._reply(200, srv.stats())
            return True
        parts = [p for p in path.split("/") if p]
        if len(parts) < 2 or parts[0] != "v1" or parts[1] != "sessions":
            return False
        if len(parts) < 3:
            return False
        sid = parts[2]
        tail = parts[3] if len(parts) > 3 else None
        if tail is None:
            if method in ("POST", "PUT"):
                body = self._body()
                for req in ("edges", "num_nodes"):
                    if req not in body:
                        raise ValueError(f"admit requires {req!r}")
                out = srv.admit(
                    sid, body["edges"], int(body["num_nodes"]),
                    weights=body.get("weights"),
                    num_clusters=body.get("num_clusters"),
                    edge_capacity=body.get("edge_capacity"))
                self._reply(200, out)
                return True
            if method == "GET":
                self._reply(200, srv.summary(sid))
                return True
            if method == "DELETE":
                out = dict(srv.evict(sid))
                out.pop("panel", None)  # not JSON-friendly at scale
                self._reply(200, out)
                return True
            return False
        if tail == "edges" and method == "POST":
            body = self._body()
            for req in ("edges", "weights"):
                if req not in body:
                    raise ValueError(f"push requires {req!r}")
            out = srv.push(sid, body["edges"], body["weights"],
                           mode=body.get("mode", "set"))
            self._reply(200, out)
            return True
        if tail == "labels" and method == "GET":
            self._reply(200, srv.labels(sid))
            return True
        return False

    def do_GET(self):
        self._dispatch("GET")

    def do_POST(self):
        self._dispatch("POST")

    def do_PUT(self):
        self._dispatch("PUT")

    def do_DELETE(self):
        self._dispatch("DELETE")


class ServeHTTP:
    """Owns the listening socket + acceptor thread over a ``Server``."""

    def __init__(self, server: Server, host: str = "127.0.0.1",
                 port: int = 0):
        self.app = server
        handler = type("BoundHandler", (_Handler,), {"server_obj": server})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self._thread: threading.Thread | None = None

    @property
    def host(self) -> str:
        return self.httpd.server_address[0]

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    def start(self) -> "ServeHTTP":
        if not self.app.running:
            self.app.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="serve-http",
            kwargs={"poll_interval": 0.1}, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Clean shutdown: stop accepting, then drain the engine."""
        self.httpd.shutdown()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self.httpd.server_close()
        self.app.stop()

    def __enter__(self) -> "ServeHTTP":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


__all__ = ["ServeHTTP"]
