"""Versioned results store: queries never touch the solve engine.

The serving layer's reads (``labels`` / ``summary``) are decoupled from
the engine by committing an immutable :class:`ResultVersion` per session
at well-defined commit points (admission, after staged updates apply,
after every tick that moved a session).  Queries are served from the
LAST COMMITTED version:

* **monotonic version ids** — per-session versions only ever increase
  (and a global commit counter orders commits across sessions), so a
  client polling ``labels`` can reason about freshness: a response
  carries the version its labels were solved under, and two responses
  with the same version are byte-identical;
* **stable cluster ids** — the store owns one
  :class:`~repro.stream.tracking.LabelTracker` per session, fed in
  commit order, so the ids a CLIENT sees are stable across re-solves /
  k-means reruns regardless of how the engine permutes its internal
  labels; per-commit :func:`~repro.stream.tracking.label_churn` is the
  measured guarantee (0.0 between consecutive queries unless the
  communities actually moved);
* **lazy labels** — committing is cheap (a summary dict + a reference
  to the immutable panel array); the k-means labelling of a version is
  materialized on FIRST query and cached on the version, under a
  per-session lock so concurrent queries do not race the tracker.

Eviction keeps the session's FINAL version queryable by default
(``drop_evicted=False`` is the server's choice) — a client that raced
an eviction still gets its 404 from the committed-tombstone state
rather than a half-removed map.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.stream import tracking
from repro.stream.service import UnknownSessionError


@dataclasses.dataclass
class ResultVersion:
    """One committed solve state of one session (immutable once built;
    ``labels``/``churn`` materialize lazily under the session lock)."""

    version: int  # per-session, monotonically increasing from 1
    commit_seq: int  # global commit order across sessions
    summary: dict  # engine session_info at commit time (+ "version")
    panel: object  # (n, k) embedding panel the labels solve from
    labels: np.ndarray | None = None  # stable ids, lazily materialized
    churn: float | None = None  # label_churn vs the previous labelling


class _SessionResults:
    __slots__ = ("lock", "tracker", "latest", "evicted")

    def __init__(self, num_clusters: int):
        self.lock = threading.Lock()
        self.tracker = tracking.LabelTracker(num_clusters)
        self.latest: ResultVersion | None = None
        self.evicted = False


class VersionedResults:
    """Map of session id -> committed result versions (latest wins)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._sessions: dict[str, _SessionResults] = {}
        self._commit_seq = 0

    # -- writes (engine/tick thread) -----------------------------------

    def register(self, sid: str, num_clusters: int) -> None:
        with self._lock:
            if sid in self._sessions and not self._sessions[sid].evicted:
                raise ValueError(f"session {sid!r} already registered")
            self._sessions[sid] = _SessionResults(num_clusters)

    def commit(self, sid: str, summary: dict, panel) -> int:
        """Commit a new version for ``sid``; returns the version id."""
        with self._lock:
            sr = self._sessions.get(sid)
            if sr is None or sr.evicted:
                raise UnknownSessionError(sid)
            self._commit_seq += 1
            seq = self._commit_seq
        with sr.lock:
            version = 1 if sr.latest is None else sr.latest.version + 1
            summary = dict(summary)
            summary["version"] = version
            sr.latest = ResultVersion(
                version=version, commit_seq=seq, summary=summary,
                panel=panel)
            return version

    def evict(self, sid: str, drop: bool = False) -> None:
        """Tombstone (default) or fully drop a session's results."""
        with self._lock:
            sr = self._sessions.get(sid)
            if sr is None or sr.evicted:
                raise UnknownSessionError(sid)
            if drop:
                del self._sessions[sid]
            else:
                sr.evicted = True

    # -- reads (query threads) -----------------------------------------

    def _live(self, sid: str) -> _SessionResults:
        with self._lock:
            sr = self._sessions.get(sid)
        if sr is None or sr.evicted or sr.latest is None:
            raise UnknownSessionError(sid)
        return sr

    def has(self, sid: str) -> bool:
        with self._lock:
            sr = self._sessions.get(sid)
            return sr is not None and not sr.evicted

    def version(self, sid: str) -> int:
        return self._live(sid).latest.version

    def summary(self, sid: str) -> dict:
        """The last committed summary (carries its ``version``)."""
        sr = self._live(sid)
        with sr.lock:
            return dict(sr.latest.summary)

    def labels(self, sid: str, labeler) -> tuple[np.ndarray, int, float]:
        """(stable labels, version, churn) of the last committed version.

        ``labeler(panel) -> raw labels`` runs at most once per version
        (cached); the raw labelling feeds the store's tracker so served
        ids stay stable across versions.  ``churn`` is the fraction of
        nodes whose stable id moved since the previously LABELLED
        version (0.0 for the first).
        """
        sr = self._live(sid)
        with sr.lock:
            rv = sr.latest
            if rv.labels is None:
                prev = sr.tracker.ref
                stable = np.asarray(sr.tracker.update(labeler(rv.panel)))
                rv.labels = stable
                rv.churn = (tracking.label_churn(np.asarray(prev), stable)
                            if prev is not None else 0.0)
            return rv.labels.copy(), rv.version, rv.churn

    def stats(self) -> dict:
        with self._lock:
            live = [s for s in self._sessions.values() if not s.evicted]
            return {
                "sessions": len(live),
                "evicted": len(self._sessions) - len(live),
                "commits": self._commit_seq,
            }


__all__ = ["ResultVersion", "VersionedResults"]
