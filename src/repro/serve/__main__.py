"""Process shell: ``python -m repro.serve`` boots the HTTP front end.

Prints one parseable banner line — ``SERVING host=<h> port=<p>`` — once
the socket is bound (port 0 picks a free port, so harnesses read the
banner rather than guessing), then serves until SIGTERM/SIGINT, which
trigger a clean shutdown: the acceptor stops, the engine thread drains
every staged batch, and the process exits 0.  ``scripts/ci.sh`` and the
bench's ``--http-smoke`` lane drive exactly this contract.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="HTTP serving front end for the streaming "
                    "spectral-clustering engine.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 = pick a free port (read the banner)")
    ap.add_argument("--pipeline", default="double_buffer",
                    choices=("double_buffer", "serialized"))
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--num-clusters", type=int, default=4)
    ap.add_argument("--degree", type=int, default=15)
    ap.add_argument("--steps-per-tick", type=int, default=20)
    ap.add_argument("--tol", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # deferred: the banner contract says nothing prints before imports
    # succeed, and jax import cost should not be paid for --help
    from repro.serve.http import ServeHTTP
    from repro.serve.server import Server, ServerConfig
    from repro.stream.service import ServiceConfig

    cfg = ServerConfig(
        service=ServiceConfig(
            k=args.k, num_clusters=args.num_clusters, degree=args.degree,
            steps_per_tick=args.steps_per_tick, tol=args.tol,
            seed=args.seed),
        pipeline=args.pipeline)
    front = ServeHTTP(Server(cfg), host=args.host, port=args.port)
    front.start()
    print(f"SERVING host={front.host} port={front.port}", flush=True)

    done = threading.Event()

    def _stop(signum, frame):
        done.set()

    signal.signal(signal.SIGTERM, _stop)
    signal.signal(signal.SIGINT, _stop)
    done.wait()
    front.stop()
    print("STOPPED", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
