"""Serving observability: latency histograms, counters, and gauges.

Pure-host, dependency-free instrumentation for the request layer.  The
design constraints come from the ingest pipeline:

* recording must be CHEAP and lock-short — every request on the hot
  path records exactly one histogram sample and a couple of counter
  bumps, so a single mutex with O(1) critical sections is enough even
  with many ingest/query threads;
* percentiles must be computable WITHOUT retaining samples — the load
  generator drives tens of thousands of requests, so latencies land in
  geometric buckets (factor ``LATENCY_BUCKET_FACTOR`` from 1us), and
  p50/p99 are read off the cumulative bucket counts.  The reported
  quantile is the upper edge of its bucket: an over-estimate by at most
  one bucket factor, i.e. SLO-conservative.

``ServeMetrics`` is the aggregate the server owns: one histogram per
request type (admit / push / labels / summary / evict), counters for
the pipeline (staged / applied / dropped batches, commits, ticks), and
gauges (queue depth, tick utilization).  ``snapshot()`` returns a plain
JSON-able dict — the payload of the HTTP front end's ``/metrics``.
"""
from __future__ import annotations

import threading
import time

LATENCY_BUCKET_FACTOR = 1.6
_BASE_S = 1e-6  # first bucket upper edge: 1 microsecond
_NUM_BUCKETS = 48  # 1.6^48 * 1us ~ 6.3e3 s: covers any sane request


def _bucket_edges() -> list[float]:
    return [_BASE_S * LATENCY_BUCKET_FACTOR ** i for i in range(_NUM_BUCKETS)]


class LatencyHistogram:
    """Fixed geometric-bucket latency histogram (seconds).

    Not internally locked — the owning :class:`ServeMetrics` serializes
    access; standalone use from one thread is fine.
    """

    __slots__ = ("counts", "count", "total_s", "max_s")

    EDGES = _bucket_edges()

    def __init__(self):
        self.counts = [0] * _NUM_BUCKETS
        self.count = 0
        self.total_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        if seconds < 0.0:
            seconds = 0.0
        lo, hi = 0, _NUM_BUCKETS - 1
        # binary search for the first bucket whose upper edge covers it
        while lo < hi:
            mid = (lo + hi) // 2
            if seconds <= self.EDGES[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.total_s += seconds
        self.max_s = max(self.max_s, seconds)

    def percentile(self, q: float) -> float:
        """Latency (seconds) at quantile ``q`` in [0, 1]: the upper edge
        of the bucket holding the q-th sample (0.0 when empty)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        rank = max(1, int(q * self.count + 0.999999))  # ceil, >= 1
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self.EDGES[i]
        return self.EDGES[-1]

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.mean_s,
            "p50_s": self.percentile(0.50),
            "p99_s": self.percentile(0.99),
            "max_s": self.max_s,
        }


class ServeMetrics:
    """Thread-safe aggregate of per-request-type latency histograms plus
    pipeline counters and gauges."""

    def __init__(self, ops: tuple[str, ...] = ()):
        self._lock = threading.Lock()
        self._hists: dict[str, LatencyHistogram] = {
            op: LatencyHistogram() for op in ops}
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._t0 = time.perf_counter()

    def record(self, op: str, seconds: float) -> None:
        with self._lock:
            h = self._hists.get(op)
            if h is None:
                h = self._hists[op] = LatencyHistogram()
            h.record(seconds)

    def timed(self, op: str):
        """Context manager: ``with metrics.timed("labels"): ...``."""
        return _Timer(self, op)

    def inc(self, counter: str, by: int = 1) -> None:
        with self._lock:
            self._counters[counter] = self._counters.get(counter, 0) + by

    def set_gauge(self, gauge: str, value: float) -> None:
        with self._lock:
            self._gauges[gauge] = float(value)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def percentile(self, op: str, q: float) -> float:
        with self._lock:
            h = self._hists.get(op)
            return h.percentile(q) if h is not None else 0.0

    def snapshot(self) -> dict:
        """JSON-able point-in-time view (the ``/metrics`` payload)."""
        with self._lock:
            return {
                "uptime_s": time.perf_counter() - self._t0,
                "latency": {op: h.summary()
                            for op, h in sorted(self._hists.items())},
                "counters": dict(sorted(self._counters.items())),
                "gauges": dict(sorted(self._gauges.items())),
            }


class _Timer:
    __slots__ = ("_metrics", "_op", "_t0")

    def __init__(self, metrics: ServeMetrics, op: str):
        self._metrics = metrics
        self._op = op

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._metrics.record(self._op, time.perf_counter() - self._t0)
        return False


__all__ = [
    "LATENCY_BUCKET_FACTOR",
    "LatencyHistogram",
    "ServeMetrics",
]
