"""laplacian_poly Pallas kernel package."""
from repro.kernels.laplacian_poly import ops, ref  # noqa: F401
