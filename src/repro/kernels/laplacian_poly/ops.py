"""jit'd public wrappers for the laplacian_poly Pallas kernels.

Handles padding to MXU-aligned block multiples and exposes the full
limit-series application -(I - sL/l)^l V as a lax.fori_loop over the
fused kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.laplacian_poly import kernel


def _pad_to(x: jax.Array, m: int, axes) -> jax.Array:
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        pads[ax] = (0, (-x.shape[ax]) % m)
    return jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x


def _pick_block(n: int) -> int:
    for b in (256, 128):
        if n % b == 0 or n > b:
            return b
    return 128


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def poly_step(l_mat: jax.Array, u: jax.Array, c, *, block: int = 0,
              interpret: bool = False) -> jax.Array:
    """out = U - c (L @ U), any n (padded internally to block multiples)."""
    n, k = u.shape
    b = block or _pick_block(n)
    lp = _pad_to(l_mat.astype(jnp.float32), b, (0, 1))
    up = _pad_to(u.astype(jnp.float32), b, (0,))
    kp = _pad_to(up, 128, (1,))  # lane-align the panel
    out = kernel.poly_step(lp, kp, c, block_m=b, block_k=b,
                           interpret=interpret)
    return out[:n, :k]


def poly_step_edges(blocking, u: jax.Array, c, *,
                    interpret: bool = False) -> jax.Array:
    """out = U - c (L @ U) on EDGE-LIST operands: the dense poly_step
    extended to matrix-free graphs via the node-blocked incidence SpMM
    (repro.kernels.edge_spmm) with the AXPY folded into its epilogue
    (alpha=-c, beta=1) — the panel never round-trips HBM between the
    matvec and the subtraction.  ``blocking`` is an
    ``edge_spmm.ops.NodeBlocking`` built once per graph.
    """
    from repro.kernels.edge_spmm import ops as es_ops
    return es_ops.edge_spmm_blocked(blocking, u, alpha=-c, beta=1.0,
                                    interpret=interpret)


def limit_series_apply_edges(blocking, v: jax.Array, *, degree: int,
                             scale: float = 1.0,
                             interpret: bool = False) -> jax.Array:
    """-(I - scale L / degree)^degree @ V, matrix-free, one fused
    node-blocked kernel per step (edge-list analogue of
    ``limit_series_apply``)."""
    c = scale / degree

    def body(_, u):
        return poly_step_edges(blocking, u, c, interpret=interpret)

    return -jax.lax.fori_loop(0, degree, body, v)


@functools.partial(jax.jit, static_argnames=("degree", "interpret", "block"))
def limit_series_apply(l_mat: jax.Array, v: jax.Array, *, degree: int,
                       scale: float = 1.0, block: int = 0,
                       interpret: bool = False) -> jax.Array:
    """-(I - scale L / degree)^degree @ V with one fused kernel per step.

    The padded L and panel stay in HBM-contiguous layout across the loop;
    each step is a single pallas_call (matmul + AXPY epilogue).
    """
    n, k = v.shape
    b = block or _pick_block(n)
    lp = _pad_to(l_mat.astype(jnp.float32), b, (0, 1))
    vp = _pad_to(_pad_to(v.astype(jnp.float32), b, (0,)), 128, (1,))
    c = scale / degree

    def body(_, u):
        return kernel.poly_step(lp, u, c, block_m=b, block_k=b,
                                interpret=interpret)

    u = jax.lax.fori_loop(0, degree, body, vp)
    return -u[:n, :k]
