"""jit'd public wrappers for the laplacian_poly Pallas kernels.

Handles padding to MXU-aligned block multiples and exposes the full
limit-series application -(I - sL/l)^l V as a lax.fori_loop over the
fused kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.laplacian_poly import kernel


def _pad_to(x: jax.Array, m: int, axes) -> jax.Array:
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        pads[ax] = (0, (-x.shape[ax]) % m)
    return jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x


def _pick_block(n: int) -> int:
    for b in (256, 128):
        if n % b == 0 or n > b:
            return b
    return 128


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def poly_step(l_mat: jax.Array, u: jax.Array, c, *, block: int = 0,
              interpret: bool = False) -> jax.Array:
    """out = U - c (L @ U), any n (padded internally to block multiples)."""
    n, k = u.shape
    b = block or _pick_block(n)
    lp = _pad_to(l_mat.astype(jnp.float32), b, (0, 1))
    up = _pad_to(u.astype(jnp.float32), b, (0,))
    kp = _pad_to(up, 128, (1,))  # lane-align the panel
    out = kernel.poly_step(lp, kp, c, block_m=b, block_k=b,
                           interpret=interpret)
    return out[:n, :k]


@functools.partial(jax.jit, static_argnames=("degree", "interpret", "block"))
def limit_series_apply(l_mat: jax.Array, v: jax.Array, *, degree: int,
                       scale: float = 1.0, block: int = 0,
                       interpret: bool = False) -> jax.Array:
    """-(I - scale L / degree)^degree @ V with one fused kernel per step.

    The padded L and panel stay in HBM-contiguous layout across the loop;
    each step is a single pallas_call (matmul + AXPY epilogue).
    """
    n, k = v.shape
    b = block or _pick_block(n)
    lp = _pad_to(l_mat.astype(jnp.float32), b, (0, 1))
    vp = _pad_to(_pad_to(v.astype(jnp.float32), b, (0,)), 128, (1,))
    c = scale / degree

    def body(_, u):
        return kernel.poly_step(lp, u, c, block_m=b, block_k=b,
                                interpret=interpret)

    u = jax.lax.fori_loop(0, degree, body, vp)
    return -u[:n, :k]
