"""Pure-jnp oracle for the laplacian_poly kernels."""
import jax
import jax.numpy as jnp


def poly_step(l_mat: jax.Array, u: jax.Array, c) -> jax.Array:
    return u - jnp.asarray(c, u.dtype) * (l_mat @ u)


def dense_matvec_panel(l_mat: jax.Array, u: jax.Array) -> jax.Array:
    return l_mat @ u


def limit_series_apply(l_mat: jax.Array, v: jax.Array, degree: int,
                       scale: float = 1.0) -> jax.Array:
    """-(I - scale L/deg)^deg @ v via the recurrence (oracle for ops)."""
    c = scale / degree
    u = v
    for _ in range(degree):
        u = poly_step(l_mat, u, c)
    return -u
