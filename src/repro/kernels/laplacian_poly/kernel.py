"""Pallas TPU kernel: fused AXPY-matmul  out = U - c * (L @ U).

This is one step of the limit-series recurrence u <- u - (L u)/l (paper
Table 2), the inner loop of SPED's deployable path.  Fusing the AXPY into
the matmul epilogue halves HBM traffic for the panel: the naive form
writes L@U to HBM and reads it back for the subtraction; here the
subtraction happens in VMEM on the final reduction step.

Tiling: L is (n, n) blocked (bm, bk) on the MXU-aligned grid
(n/bm, n/bk); U is an (n, k) panel blocked (bk, k).  The (bm, k)
accumulator lives in the output ref (f32) across the reduction dimension
— revisited blocks stay resident in VMEM (Mosaic guarantees the output
block is carried across grid steps that map to the same output tile when
the reduction dimension is the innermost grid axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _poly_step_kernel(l_ref, u_in_ref, u_row_ref, c_ref, out_ref):
    """Grid (i, j): out[i] accumulates sum_j L[i,j] @ U[j]; on the last j
    the epilogue rewrites out[i] = U[i] - c * acc."""
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        l_ref[...], u_in_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _epilogue():
        c = c_ref[0]
        out_ref[...] = u_row_ref[...] - c * out_ref[...]


def poly_step(l_mat: jax.Array, u: jax.Array, c: float | jax.Array,
              *, block_m: int = 256, block_k: int = 256,
              interpret: bool = False) -> jax.Array:
    """out = U - c * (L @ U).  Shapes: L (n, n), U (n, k); n % block == 0
    (the ops.py wrapper pads)."""
    n, k = u.shape
    assert l_mat.shape == (n, n)
    assert n % block_m == 0 and n % block_k == 0, (n, block_m, block_k)
    c_arr = jnp.asarray(c, jnp.float32).reshape(1)
    grid = (n // block_m, n // block_k)
    return pl.pallas_call(
        _poly_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),  # L tile
            pl.BlockSpec((block_k, k), lambda i, j: (j, 0)),  # U (reduce)
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),  # U (row, AXPY)
            pl.BlockSpec((1,), lambda i, j: (0,)),  # c scalar
        ],
        out_specs=pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(l_mat, u, u, c_arr)


def _matmul_kernel(a_ref, b_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def dense_matvec_panel(l_mat: jax.Array, u: jax.Array,
                       *, block_m: int = 256, block_k: int = 256,
                       interpret: bool = False) -> jax.Array:
    """Plain tiled L @ U (the baseline the fused kernel is measured
    against in benchmarks)."""
    n, k = u.shape
    assert n % block_m == 0 and n % block_k == 0
    grid = (n // block_m, n // block_k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_k, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(l_mat, u)
