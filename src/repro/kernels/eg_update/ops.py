"""jit'd fused mu-EigenGame update: two Pallas passes over the panels plus
O(k^3) coefficient algebra on tiny matrices."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.eg_update import kernel, ref


def _pad_rows(x: jax.Array, m: int) -> jax.Array:
    pad = (-x.shape[0]) % m
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def mu_eg_update(v: jax.Array, av: jax.Array, lr: float,
                 *, block_n: int = 512, interpret: bool = False) -> jax.Array:
    """Fused mu-EG step == ref.mu_eg_update (oracle), 2 panel passes."""
    n, k = v.shape
    pad_k = (-k) % 128
    vp = _pad_rows(jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pad_k))), block_n)
    avp = _pad_rows(jnp.pad(av.astype(jnp.float32), ((0, 0), (0, pad_k))), block_n)
    kk = k + pad_k
    s2 = kernel.gram2k(vp, avp, block_n=block_n, interpret=interpret)
    # un-pad the gram back to 2k x 2k ordering [V | AV]
    s2 = jnp.concatenate([
        jnp.concatenate([s2[:k, :k], s2[:k, kk: kk + k]], axis=1),
        jnp.concatenate([s2[kk: kk + k, :k], s2[kk: kk + k, kk: kk + k]], axis=1),
    ], axis=0)
    m1, m2, colscale = ref.coefficient_matrices(s2, k, lr)
    m1p = jnp.pad(m1, ((0, pad_k), (0, pad_k)))
    m2p = jnp.pad(m2, ((0, pad_k), (0, pad_k)))
    csp = jnp.pad(colscale, (0, pad_k))
    out = kernel.panel_mix(vp, avp, m1p, m2p, csp, block_n=block_n,
                           interpret=interpret)
    return out[:n, :k]
