"""Pure-jnp oracle for the fused mu-EG update (mirrors solvers.mu_eg_step)."""
import jax
import jax.numpy as jnp


def mu_eg_update(v: jax.Array, av: jax.Array, lr: float) -> jax.Array:
    k = v.shape[1]
    vav = v.T @ av
    lower = jnp.tril(jnp.ones((k, k), v.dtype), k=-1)
    penalties = v @ (lower * vav).T
    grad = av - penalties
    grad = grad - v * jnp.sum(v * grad, axis=0, keepdims=True)
    vn = v + lr * grad
    return vn / jnp.maximum(jnp.linalg.norm(vn, axis=0, keepdims=True), 1e-30)


def coefficient_matrices(s2: jax.Array, k: int, lr: float):
    """Derive (M1, M2, colscale) from the 2k x 2k gram of [V | AV] such
    that mu_eg_update(V, AV) == (V @ M1 + AV @ M2) * colscale.

    Algebra: penalties = V C0 with C0 = (tril(vav,-1))^T;
    Riemannian coefficient d = diag(vav) - diag(vv C0);
    V + lr grad = V M1 + AV M2, M1 = I - lr (C0 + diag(d)), M2 = lr I;
    col norms^2 = diag([M1; M2]^T S2 [M1; M2]).
    """
    vv = s2[:k, :k]
    vav = s2[:k, k:]
    avav = s2[k:, k:]
    eye = jnp.eye(k, dtype=s2.dtype)
    lower = jnp.tril(jnp.ones((k, k), s2.dtype), k=-1)
    c0 = (lower * vav).T
    d = jnp.diagonal(vav) - jnp.diagonal(vv @ c0)
    m1 = eye - lr * (c0 + jnp.diag(d))
    m2 = lr * eye
    norm2 = (
        jnp.diagonal(m1.T @ vv @ m1)
        + jnp.diagonal(m1.T @ vav @ m2)
        + jnp.diagonal(m2.T @ vav.T @ m1)
        + jnp.diagonal(m2.T @ avav @ m2)
    )
    colscale = jax.lax.rsqrt(jnp.maximum(norm2, 1e-60))
    return m1, m2, colscale
