"""eg_update Pallas kernel package."""
from repro.kernels.eg_update import ops, ref  # noqa: F401
