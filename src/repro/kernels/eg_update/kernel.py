"""Pallas TPU kernels for the fused mu-EigenGame update.

A mu-EG step (paper Sec. 5.1; Gemp et al. 2021b) on a panel V with
operator output AV is, in matrix form:

    vav  = V^T A V                         (k, k)
    grad = AV - V (tril(vav, -1))^T        penalties from parents
    grad = grad - V diag(colsum(V * grad)) Riemannian projection
    V'   = colnormalize(V + lr grad)

Every term after the grams is a LINEAR combination V' = (V M1 + AV M2) S
with k x k coefficient matrices computed from the grams of [V | AV]
(ops.py does that tiny k x k algebra in plain jnp).  So the whole update
needs exactly TWO passes over the (n, k) panels:

  * gram2k:    S2 = [V|AV]^T [V|AV]   — one fused tiled reduction
  * panel_mix: V' = (V @ M1 + AV @ M2) * colscale — one fused pass

versus ~7 separate elementwise/matmul passes in the naive form.  This is
the paper's solver inner loop made HBM-minimal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram2k_kernel(v_ref, av_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cat = jnp.concatenate([v_ref[...], av_ref[...]], axis=1)  # (bn, 2k)
    out_ref[...] += jnp.dot(cat.T, cat, preferred_element_type=jnp.float32)


def gram2k(v: jax.Array, av: jax.Array, *, block_n: int = 512,
           interpret: bool = False) -> jax.Array:
    """S = [V|AV]^T [V|AV]  (2k, 2k); n % block_n == 0 (ops pads)."""
    n, k = v.shape
    assert n % block_n == 0
    return pl.pallas_call(
        _gram2k_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((2 * k, 2 * k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2 * k, 2 * k), jnp.float32),
        interpret=interpret,
    )(v, av)


def _panel_mix_kernel(v_ref, av_ref, m1_ref, m2_ref, scale_ref, out_ref):
    acc = jnp.dot(v_ref[...], m1_ref[...], preferred_element_type=jnp.float32)
    acc += jnp.dot(av_ref[...], m2_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = acc * scale_ref[0:1, :]


def panel_mix(v: jax.Array, av: jax.Array, m1: jax.Array, m2: jax.Array,
              colscale: jax.Array, *, block_n: int = 512,
              interpret: bool = False) -> jax.Array:
    """V' = (V @ M1 + AV @ M2) * colscale, one pass over the panels."""
    n, k = v.shape
    assert n % block_n == 0
    scale2d = jnp.broadcast_to(colscale.reshape(1, k), (8, k))
    return pl.pallas_call(
        _panel_mix_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((8, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(v, av, m1, m2, scale2d)
