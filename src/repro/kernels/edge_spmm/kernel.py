"""Pallas TPU kernels: incidence SpMM  Y = X^T W (X V), one-hot and
node-blocked variants, with a fused affine epilogue.

The stochastic heart of SPED (paper Sec. 3/4.3): a batch of E edges
defines incidence rows x_e (+1 at src, -1 at dst); the Laplacian
(estimate) applied to the panel V is

    Y = sum_e w_e x_e (x_e^T V)  =  X^T diag(w) X V.

GPU implementations scatter-add per edge.  TPUs have no efficient
scatter, so the TPU-native adaptation (DESIGN.md Sec. 3) materializes
one-hot incidence BLOCKS in VMEM and rides the MXU:

one-hot variant (``edge_spmm``, n <= ONE_HOT_NODE_LIMIT = 4096):

    X_blk = onehot(src) - onehot(dst)          (BE, n)   built via iota
    D     = X_blk @ V                           (BE, k)   MXU
    Y    += X_blk^T @ (w * D)                   (n, k)    MXU

Grid over edge blocks; Y accumulates in the output ref.  V is assumed to
fit VMEM (n x k panels with k <= 128; the backend layer caps this
variant at n <= ONE_HOT_NODE_LIMIT = 4096 — the small-graph
spectral-clustering regime).

node-blocked variant (``edge_spmm_nb``, any n):

    L v = deg * v - A v  decomposes the matvec into an elementwise
    degree term and an adjacency SpMM.  Host code (ops.py) expands each
    edge into two directed half-edges (u <- o, weight w), buckets them
    by the node-block of the DESTINATION u, and pre-gathers the source
    rows G = V[o].  The kernel then only ever holds a (block_n, k)
    panel slice plus a (BE, block_n) LOCAL one-hot in VMEM:

    out[b]  = deg[b] * V[b]                     (init, first chunk of b)
    out[b] -= onehot(u_local)^T @ (w * G_chunk) (BE, block_n) MXU per chunk

    The chunk layout is CSR-style VARIABLE-per-block: a hub node-block
    owns many chunks, a sparse one owns a single chunk, and the grid is
    1-D over TOTAL chunks.  A scalar-prefetched chunk->block index map
    (``PrefetchScalarGridSpec``) steers the deg/panel/output BlockSpecs
    to the right node-block per chunk, so skewed (power-law) graphs pay
    sum-of-chunks work instead of blocks * max-chunks uniform padding.
    Chunks arrive sorted by block, so each output block is revisited
    contiguously (the Pallas revisiting contract: the block accumulates
    in VMEM across its run and writes back once) and the per-block init/
    epilogue fire on the first/last chunk of the run, detected from the
    prefetched map.  Each (BE, k) gathered slice streams HBM->VMEM via
    the standard Pallas grid pipeline, i.e. the slice for chunk j+1 is
    double-buffered behind chunk j's MXU work.

Both kernels end with the fused AFFINE EPILOGUE

    out = alpha * (L V)_block + beta * V_block

on the last grid step, which folds one series-recurrence step — the
limit-series u <- u - c (L u) (alpha=-c, beta=1) or the Chebyshev/
Clenshaw t(L) u = a L u + b u — into the SpMM so the panel never
round-trips HBM between the matvec and the AXPY.  alpha=1, beta=0
recovers the plain matvec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _edge_spmm_kernel(src_ref, dst_ref, w_ref, v_ref, ab_ref, out_ref):
    e = pl.program_id(0)
    ne = pl.num_programs(0)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    n = v_ref.shape[0]
    be = src_ref.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (be, n), 1)
    oh_src = (src_ref[...][:, None] == cols).astype(jnp.float32)
    oh_dst = (dst_ref[...][:, None] == cols).astype(jnp.float32)
    x_blk = oh_src - oh_dst  # (BE, n) incidence rows
    d = jnp.dot(x_blk, v_ref[...], preferred_element_type=jnp.float32)
    wd = w_ref[...][:, None] * d
    out_ref[...] += jnp.dot(x_blk.T, wd, preferred_element_type=jnp.float32)

    @pl.when(e == ne - 1)
    def _epilogue():
        out_ref[...] = ab_ref[0] * out_ref[...] + ab_ref[1] * v_ref[...]


def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array, v: jax.Array,
              ab: jax.Array | None = None,
              *, block_e: int = 128, interpret: bool = False) -> jax.Array:
    """Y = alpha * sum_e w_e x_e x_e^T V + beta * V over the edge batch.
    ``ab`` is the (2,) [alpha, beta] epilogue (default [1, 0] == plain
    matvec).  E % block_e == 0 (ops.py pads with zero-weight edges)."""
    e = src.shape[0]
    n, k = v.shape
    assert e % block_e == 0, (e, block_e)
    if ab is None:
        ab = jnp.asarray([1.0, 0.0], jnp.float32)
    grid = (e // block_e,)
    return pl.pallas_call(
        _edge_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(src, dst, w, v, ab)


def _edge_spmm_nb_kernel(cb_ref, u_ref, w_ref, g_ref, deg_ref, v_ref,
                         ab_ref, out_ref):
    j = pl.program_id(0)
    nc = pl.num_programs(0)
    blk = cb_ref[j]
    # First/last chunk of this block's (contiguous, block-sorted) run.
    # cb_ref has nc + 1 entries; the tail sentinel repeats the last block
    # so cb_ref[j + 1] is always in bounds and never opens a new run.
    prev = cb_ref[jnp.maximum(j - 1, 0)]
    is_first = jnp.logical_or(j == 0, prev != blk)
    is_last = jnp.logical_or(j == nc - 1, cb_ref[j + 1] != blk)

    @pl.when(is_first)
    def _init():
        out_ref[...] = deg_ref[...][:, None] * v_ref[...]

    bn = out_ref.shape[0]
    be = u_ref.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (be, bn), 1)
    oh = (u_ref[...][:, None] == cols).astype(jnp.float32)  # local dest
    out_ref[...] -= jnp.dot(
        oh.T, w_ref[...][:, None] * g_ref[...],
        preferred_element_type=jnp.float32)

    @pl.when(is_last)
    def _epilogue():
        out_ref[...] = ab_ref[0] * out_ref[...] + ab_ref[1] * v_ref[...]


def edge_spmm_nb(u_local: jax.Array, w: jax.Array, gathered: jax.Array,
                 chunk_block: jax.Array, deg: jax.Array, v: jax.Array,
                 ab: jax.Array, *, block_n: int, block_e: int,
                 num_chunks: int, interpret: bool = False) -> jax.Array:
    """Node-blocked Y = alpha * (L V) + beta * V, variable chunks/block.

    Half-edges are bucketed by destination node-block into a CSR-style
    chunk list (ops.build_node_blocking): ``chunk_block`` maps each of
    the ``num_chunks`` grid steps to its node-block, every block owns at
    least one chunk, and padding chunks extend the LAST block's run with
    zero weights.  The map is scalar-prefetched so the deg/panel/output
    BlockSpecs below index data-dependently per chunk; source rows are
    pre-gathered into ``gathered`` = V[other] and streamed (BE, k) at a
    time by the grid pipeline.  VMEM per grid step: one (block_n, k)
    panel slice, one (block_e, k) gathered chunk, and the
    (block_e, block_n) local one-hot — independent of total n and of
    graph skew.
    """
    np_, k = v.shape
    assert np_ % block_n == 0, (np_, block_n)
    assert u_local.shape[0] == num_chunks * block_e, \
        (u_local.shape, num_chunks, block_e)
    assert chunk_block.shape[0] == num_chunks + 1, \
        (chunk_block.shape, num_chunks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_chunks,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda j, cb: (j,)),
            pl.BlockSpec((block_e,), lambda j, cb: (j,)),
            pl.BlockSpec((block_e, k), lambda j, cb: (j, 0)),
            pl.BlockSpec((block_n,), lambda j, cb: (cb[j],)),
            pl.BlockSpec((block_n, k), lambda j, cb: (cb[j], 0)),
            pl.BlockSpec((2,), lambda j, cb: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda j, cb: (cb[j], 0)),
    )
    return pl.pallas_call(
        _edge_spmm_nb_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((np_, k), jnp.float32),
        interpret=interpret,
    )(chunk_block, u_local, w, gathered, deg, v, ab)
