"""Pallas TPU kernel: minibatch incidence SpMM  Y = X_b^T W_b (X_b V).

The stochastic heart of SPED (paper Sec. 3/4.3): a minibatch of B edges
defines incidence rows x_e (+1 at src, -1 at dst); the unbiased Laplacian
estimate applied to the panel V is

    Y = sum_e w_e x_e (x_e^T V)  =  X_b^T diag(w) X_b V.

GPU implementations scatter-add per edge.  TPUs have no efficient
scatter, so the TPU-native adaptation (DESIGN.md Sec. 3) materializes the
one-hot incidence BLOCK in VMEM and rides the MXU twice:

    X_blk = onehot(src) - onehot(dst)          (BE, n)   built via iota
    D     = X_blk @ V                           (BE, k)   MXU
    Y    += X_blk^T @ (w * D)                   (n, k)    MXU

Grid over edge blocks; Y accumulates in the output ref.  V is assumed to
fit VMEM (n x k panels with n <= ~8k, k <= 128 — the spectral-clustering
regime; larger n uses the node-blocked variant in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_spmm_kernel(src_ref, dst_ref, w_ref, v_ref, out_ref):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    n = v_ref.shape[0]
    be = src_ref.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (be, n), 1)
    oh_src = (src_ref[...][:, None] == cols).astype(jnp.float32)
    oh_dst = (dst_ref[...][:, None] == cols).astype(jnp.float32)
    x_blk = oh_src - oh_dst  # (BE, n) incidence rows
    d = jnp.dot(x_blk, v_ref[...], preferred_element_type=jnp.float32)
    wd = w_ref[...][:, None] * d
    out_ref[...] += jnp.dot(x_blk.T, wd, preferred_element_type=jnp.float32)


def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array, v: jax.Array,
              *, block_e: int = 128, interpret: bool = False) -> jax.Array:
    """Y = sum_e w_e x_e x_e^T V over the edge minibatch.  E % block_e == 0
    (ops.py pads with zero-weight edges)."""
    e = src.shape[0]
    n, k = v.shape
    assert e % block_e == 0, (e, block_e)
    grid = (e // block_e,)
    return pl.pallas_call(
        _edge_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((n, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(src, dst, w, v)
