"""jit'd wrappers for the edge_spmm kernels.

``edge_spmm`` pads edges to block multiples (zero weight => no
contribution) and lane-aligns the panel; it holds the full (n, k) panel
plus a (block_e, n) one-hot in VMEM, so the backend layer only selects
it up to ``repro.core.backend.ONE_HOT_NODE_LIMIT`` (4096) nodes.

``build_node_blocking`` + ``edge_spmm_blocked`` are the scalable path:
edges are expanded host-side into directed half-edges (u <- o, w) and
bucketed by the node-block of the destination u, with per-bucket chunk
counts SNAPPED to powers of two so graphs of similar skew share one
compiled program (the streaming store's capacity-class economics).  The
kernel then works on (block_n, k) panel slices only — see kernel.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_spmm import kernel


def _ab(alpha, beta) -> jax.Array:
    a = jnp.asarray(alpha, jnp.float32).reshape(())
    b = jnp.asarray(beta, jnp.float32).reshape(())
    return jnp.stack([a, b])


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array, v: jax.Array,
              alpha=1.0, beta=0.0,
              *, block_e: int = 128, interpret: bool = False) -> jax.Array:
    """alpha * (sum_e w_e x_e x_e^T V) + beta * V; default plain matvec.

    Accepts (n,) or (n, k) panels (1-D round-trips through a column).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    e = src.shape[0]
    n, k = v.shape
    # an edgeless input still needs one (inert) block: a zero-size grid
    # is invalid, and the segment backend returns zeros there
    pad_e = block_e if e == 0 else (-e) % block_e
    if pad_e:
        src = jnp.concatenate([src, jnp.zeros((pad_e,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.ones((pad_e,), dst.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad_e,), w.dtype)])
    pad_k = (-k) % 128
    pad_n = (-n) % 8  # sublane alignment
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad_n), (0, pad_k)))
    out = kernel.edge_spmm(src, dst, w.astype(jnp.float32), vp,
                           _ab(alpha, beta),
                           block_e=block_e, interpret=interpret)
    out = out[:n, :k]
    return out[:, 0] if squeeze else out


class NodeBlocking(NamedTuple):
    """Node-blocked half-edge layout for ``edge_spmm_blocked``.

    Built host-side ONCE per graph (or per capacity-class admission in
    the streaming graph store) and cached alongside the padded edge
    buffers; every matvec/fused-series-step reuses it.  Arrays are
    device-resident; the ints are static and part of the compile key.
    """

    u_local: jax.Array  # (NB*C*BE,) int32 — dest index local to its block
    other: jax.Array  # (NB*C*BE,) int32 — global source node per half-edge
    weight: jax.Array  # (NB*C*BE,) float32 — 0 => padding slot
    deg: jax.Array  # (NB*block_n,) float32 — weighted degrees, row-padded
    block_n: int  # nodes per block (static)
    block_e: int  # half-edges per kernel chunk (static)
    chunks_per_block: int  # C, uniform per bucket (static, pow2-snapped)
    num_nodes: int  # real node count n (static); NB = ceil(n / block_n)

    @property
    def num_blocks(self) -> int:
        return self.deg.shape[0] // self.block_n

    @property
    def padded_nodes(self) -> int:
        return self.deg.shape[0]


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1) — shared by the blocking's
    chunk snapping here and the service's occupancy buckets."""
    return 1 << max(int(np.ceil(np.log2(max(int(x), 1)))), 0)


def build_node_blocking(src, dst, weight, num_nodes: int,
                        *, block_n: int = 512, block_e: int = 128,
                        snap_chunks: bool = True) -> NodeBlocking:
    """Host-side (numpy) bucketing of edges by destination node-block.

    Each undirected edge (s, d, w) becomes two half-edges — out[s] takes
    +w*(v[s]-v[d]), out[d] takes +w*(v[d]-v[s]) — and L v = deg*v - A v
    lets the kernel carry the v[u] part as a precomputed degree, so a
    half-edge only records (u_local, other, w).  Zero-weight slots
    (capacity padding in the streaming store) are DROPPED here: they are
    inert anyway, and keeping them would pile the entire padding into
    node-block 0 and destroy bucket uniformity.  Buckets are padded to a
    uniform chunk count C (`snap_chunks` rounds C to a power of two so
    the compile key — and therefore the compiled-program count — stays
    logarithmic in graph skew).
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    live = weight != 0.0
    src, dst, weight = src[live], dst[live], weight[live]
    nb = max((num_nodes + block_n - 1) // block_n, 1)
    n_pad = nb * block_n
    # directed half-edges: destination u, source o
    u = np.concatenate([src, dst])
    o = np.concatenate([dst, src])
    w2 = np.concatenate([weight, weight])
    blk = u // block_n
    order = np.argsort(blk, kind="stable")  # deterministic layout
    u, o, w2, blk = u[order], o[order], w2[order], blk[order]
    counts = np.bincount(blk, minlength=nb)
    c = max(int(np.ceil(counts.max(initial=0) / block_e)), 1)
    if snap_chunks:
        c = next_pow2(c)
    ul = np.zeros((nb, c * block_e), np.int32)
    ot = np.zeros((nb, c * block_e), np.int32)
    wt = np.zeros((nb, c * block_e), np.float32)
    offs = np.concatenate([[0], np.cumsum(counts)])
    for b in range(nb):
        lo, hi = offs[b], offs[b + 1]
        m = hi - lo
        ul[b, :m] = u[lo:hi] - b * block_n
        ot[b, :m] = o[lo:hi]
        wt[b, :m] = w2[lo:hi]
    deg = np.zeros((n_pad,), np.float32)
    np.add.at(deg, src, weight)
    np.add.at(deg, dst, weight)
    return NodeBlocking(
        u_local=jnp.asarray(ul.reshape(-1)),
        other=jnp.asarray(ot.reshape(-1)),
        weight=jnp.asarray(wt.reshape(-1)),
        deg=jnp.asarray(deg),
        block_n=block_n,
        block_e=block_e,
        chunks_per_block=c,
        num_nodes=int(num_nodes),
    )


@functools.partial(jax.jit, static_argnames=(
    "block_n", "block_e", "chunks_per_block", "interpret"))
def _edge_spmm_blocked(u_local, other, weight, deg, v, ab,
                       *, block_n: int, block_e: int,
                       chunks_per_block: int, interpret: bool):
    n, k = v.shape
    n_pad = deg.shape[0]
    pad_k = (-k) % 128
    vp = jnp.pad(v.astype(jnp.float32), ((0, n_pad - n), (0, pad_k)))
    gathered = vp[other]  # (NB*C*BE, kp) XLA gather; the scatter is MXU
    out = kernel.edge_spmm_nb(
        u_local, weight, gathered, deg, vp, ab,
        block_n=block_n, block_e=block_e,
        chunks_per_block=chunks_per_block, interpret=interpret)
    return out[:n, :k]


def edge_spmm_blocked(nb: NodeBlocking, v: jax.Array,
                      alpha=1.0, beta=0.0,
                      *, interpret: bool = False) -> jax.Array:
    """alpha * (L V) + beta * V via the node-blocked kernel.

    Accepts (n,) or (n, k) with n == nb.num_nodes; alpha/beta may be
    traced scalars (the streaming service's per-session dilation scale).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    if v.shape[0] != nb.num_nodes:
        raise ValueError(
            f"panel rows {v.shape[0]} != blocking num_nodes {nb.num_nodes}")
    out = _edge_spmm_blocked(
        nb.u_local, nb.other, nb.weight, nb.deg, v, _ab(alpha, beta),
        block_n=nb.block_n, block_e=nb.block_e,
        chunks_per_block=nb.chunks_per_block, interpret=interpret)
    return out[:, 0] if squeeze else out
