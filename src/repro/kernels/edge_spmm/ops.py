"""jit'd wrapper for edge_spmm: pads edges to block multiples (zero weight
=> no contribution) and lane-aligns the panel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_spmm import kernel


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array, v: jax.Array,
              *, block_e: int = 128, interpret: bool = False) -> jax.Array:
    e = src.shape[0]
    n, k = v.shape
    pad_e = (-e) % block_e
    if pad_e:
        src = jnp.concatenate([src, jnp.zeros((pad_e,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.ones((pad_e,), dst.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad_e,), w.dtype)])
    pad_k = (-k) % 128
    pad_n = (-n) % 8  # sublane alignment
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad_n), (0, pad_k)))
    out = kernel.edge_spmm(src, dst, w.astype(jnp.float32), vp,
                           block_e=block_e, interpret=interpret)
    return out[:n, :k]
