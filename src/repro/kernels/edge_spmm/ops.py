"""jit'd wrappers for the edge_spmm kernels.

``edge_spmm`` pads edges to block multiples (zero weight => no
contribution) and lane-aligns the panel; it holds the full (n, k) panel
plus a (block_e, n) one-hot in VMEM, so the backend layer only selects
it up to ``repro.core.backend.ONE_HOT_NODE_LIMIT`` (4096) nodes.

``build_node_blocking`` + ``edge_spmm_blocked`` are the scalable path:
edges are expanded host-side into directed half-edges (u <- o, w) and
bucketed by the node-block of the destination u, with per-bucket chunk
counts SNAPPED to powers of two so graphs of similar skew share one
compiled program (the streaming store's capacity-class economics).  The
kernel then works on (block_n, k) panel slices only — see kernel.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_spmm import kernel


def _ab(alpha, beta) -> jax.Array:
    a = jnp.asarray(alpha, jnp.float32).reshape(())
    b = jnp.asarray(beta, jnp.float32).reshape(())
    return jnp.stack([a, b])


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array, v: jax.Array,
              alpha=1.0, beta=0.0,
              *, block_e: int = 128, interpret: bool = False) -> jax.Array:
    """alpha * (sum_e w_e x_e x_e^T V) + beta * V; default plain matvec.

    Accepts (n,) or (n, k) panels (1-D round-trips through a column).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    e = src.shape[0]
    n, k = v.shape
    # an edgeless input still needs one (inert) block: a zero-size grid
    # is invalid, and the segment backend returns zeros there
    pad_e = block_e if e == 0 else (-e) % block_e
    if pad_e:
        src = jnp.concatenate([src, jnp.zeros((pad_e,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.ones((pad_e,), dst.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad_e,), w.dtype)])
    pad_k = (-k) % 128
    pad_n = (-n) % 8  # sublane alignment
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad_n), (0, pad_k)))
    out = kernel.edge_spmm(src, dst, w.astype(jnp.float32), vp,
                           _ab(alpha, beta),
                           block_e=block_e, interpret=interpret)
    out = out[:n, :k]
    return out[:, 0] if squeeze else out


class NodeBlocking(NamedTuple):
    """Node-blocked half-edge layout for ``edge_spmm_blocked``.

    Built host-side ONCE per graph (or per capacity-class admission in
    the streaming graph store) and cached alongside the padded edge
    buffers; every matvec/fused-series-step reuses it.  Arrays are
    device-resident; the ints are static and part of the compile key.
    """

    u_local: jax.Array  # (NB*C*BE,) int32 — dest index local to its block
    other: jax.Array  # (NB*C*BE,) int32 — global source node per half-edge
    weight: jax.Array  # (NB*C*BE,) float32 — 0 => padding slot
    deg: jax.Array  # (NB*block_n,) float32 — weighted degrees, row-padded
    block_n: int  # nodes per block (static)
    block_e: int  # half-edges per kernel chunk (static)
    chunks_per_block: int  # C, uniform per bucket (static, pow2-snapped)
    num_nodes: int  # real node count n (static); NB = ceil(n / block_n)

    @property
    def num_blocks(self) -> int:
        return self.deg.shape[0] // self.block_n

    @property
    def padded_nodes(self) -> int:
        return self.deg.shape[0]


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1) — shared by the blocking's
    chunk snapping here and the service's occupancy buckets."""
    return 1 << max(int(np.ceil(np.log2(max(int(x), 1)))), 0)


def _block_sorted_half_edges(src, dst, weight, block_n: int, nb: int):
    """Live edges -> directed half-edges sorted by destination node-block.

    Returns (u, o, w2, counts): half-edge destination/source/weight in
    deterministic block order plus per-block half-edge counts.  Shared
    by the single-device and per-shard blocking builders so both lay
    half-edges out identically.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    live = weight != 0.0
    src, dst, weight = src[live], dst[live], weight[live]
    # directed half-edges: destination u, source o
    u = np.concatenate([src, dst])
    o = np.concatenate([dst, src])
    w2 = np.concatenate([weight, weight])
    blk = u // block_n
    order = np.argsort(blk, kind="stable")  # deterministic layout
    counts = np.bincount(blk[order], minlength=nb)
    return u[order], o[order], w2[order], counts


def _chunks_for_counts(counts, block_e: int, snap_chunks: bool) -> int:
    c = max(int(np.ceil(counts.max(initial=0) / block_e)), 1)
    return next_pow2(c) if snap_chunks else c


def _fill_buckets(u, o, w2, counts, nb: int, c: int,
                  block_n: int, block_e: int):
    """Scatter block-sorted half-edges into the uniform (nb, c*block_e)
    bucket layout; unfilled tail slots stay zero-weight (inert)."""
    ul = np.zeros((nb, c * block_e), np.int32)
    ot = np.zeros((nb, c * block_e), np.int32)
    wt = np.zeros((nb, c * block_e), np.float32)
    offs = np.concatenate([[0], np.cumsum(counts)])
    for b in range(nb):
        lo, hi = offs[b], offs[b + 1]
        m = hi - lo
        ul[b, :m] = u[lo:hi] - b * block_n
        ot[b, :m] = o[lo:hi]
        wt[b, :m] = w2[lo:hi]
    return ul, ot, wt


def _weighted_degrees(src, dst, weight, n_pad: int):
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    live = weight != 0.0
    deg = np.zeros((n_pad,), np.float32)
    np.add.at(deg, src[live], weight[live])
    np.add.at(deg, dst[live], weight[live])
    return deg


def build_node_blocking(src, dst, weight, num_nodes: int,
                        *, block_n: int = 512, block_e: int = 128,
                        snap_chunks: bool = True) -> NodeBlocking:
    """Host-side (numpy) bucketing of edges by destination node-block.

    Each undirected edge (s, d, w) becomes two half-edges — out[s] takes
    +w*(v[s]-v[d]), out[d] takes +w*(v[d]-v[s]) — and L v = deg*v - A v
    lets the kernel carry the v[u] part as a precomputed degree, so a
    half-edge only records (u_local, other, w).  Zero-weight slots
    (capacity padding in the streaming store) are DROPPED here: they are
    inert anyway, and keeping them would pile the entire padding into
    node-block 0 and destroy bucket uniformity.  Buckets are padded to a
    uniform chunk count C (`snap_chunks` rounds C to a power of two so
    the compile key — and therefore the compiled-program count — stays
    logarithmic in graph skew).
    """
    nb = max((num_nodes + block_n - 1) // block_n, 1)
    n_pad = nb * block_n
    u, o, w2, counts = _block_sorted_half_edges(src, dst, weight,
                                                block_n, nb)
    c = _chunks_for_counts(counts, block_e, snap_chunks)
    ul, ot, wt = _fill_buckets(u, o, w2, counts, nb, c, block_n, block_e)
    deg = _weighted_degrees(src, dst, weight, n_pad)
    return NodeBlocking(
        u_local=jnp.asarray(ul.reshape(-1)),
        other=jnp.asarray(ot.reshape(-1)),
        weight=jnp.asarray(wt.reshape(-1)),
        deg=jnp.asarray(deg),
        block_n=block_n,
        block_e=block_e,
        chunks_per_block=c,
        num_nodes=int(num_nodes),
    )


class ShardedNodeBlocking(NamedTuple):
    """Per-shard node-blocked half-edge layouts for mesh-parallel matvecs.

    The edge buffer is split into ``num_shards`` contiguous slices (the
    :func:`repro.core.distributed.pad_edges_for_mesh` contract) and each
    slice is bucketed INDEPENDENTLY by destination node-block, exactly
    like :func:`build_node_blocking` does for the whole buffer.  All
    shards share ONE static layout — the chunk count is pow2-snapped to
    the worst shard — so the stacked arrays drop straight into a
    ``shard_map`` with the shard axis partitioned over the mesh's edge
    axes, and every shard compiles against identical shapes.

    The matvec decomposes per shard as ``L_s v = deg_s * v - A_s v``
    with ``deg_s`` the weighted degrees of THAT SHARD's edges only, so
    the one psum of the (n, k) panel reconstructs
    ``sum_s L_s v = L v`` exactly (no double-counted diagonal).  A shard
    whose slice holds zero live edges (all capacity padding) gets an
    all-zero layout in the same shapes: its kernel output is exactly
    zero and the psum is unaffected.
    """

    u_local: jax.Array  # (S, NB*C*BE) int32 — dest index local to block
    other: jax.Array  # (S, NB*C*BE) int32 — global source node
    weight: jax.Array  # (S, NB*C*BE) float32 — 0 => padding slot
    deg: jax.Array  # (S, NB*block_n) float32 — PER-SHARD weighted degrees
    block_n: int  # static
    block_e: int  # static
    chunks_per_block: int  # C, shared across shards (static, pow2)
    num_nodes: int  # real node count n (static)
    num_shards: int  # S (static)

    @property
    def num_blocks(self) -> int:
        return self.deg.shape[1] // self.block_n

    @property
    def padded_nodes(self) -> int:
        return self.deg.shape[1]

    def shard(self, s: int) -> NodeBlocking:
        """Single-shard view — what one mesh device computes with."""
        return NodeBlocking(
            u_local=self.u_local[s], other=self.other[s],
            weight=self.weight[s], deg=self.deg[s],
            block_n=self.block_n, block_e=self.block_e,
            chunks_per_block=self.chunks_per_block,
            num_nodes=self.num_nodes)

    @property
    def statics(self) -> dict:
        """The compile-key statics, as kwargs for
        :func:`shard_local_blocking` (and tick-program builders)."""
        return dict(block_n=self.block_n, block_e=self.block_e,
                    chunks_per_block=self.chunks_per_block,
                    num_nodes=self.num_nodes)


def shard_local_blocking(u_local, other, weight, deg, *, block_n: int,
                         block_e: int, chunks_per_block: int,
                         num_nodes: int) -> NodeBlocking:
    """One device's NodeBlocking from shard_map-LOCAL slices of a
    :class:`ShardedNodeBlocking`'s stacked arrays (the leading shard
    axis is partitioned down to size 1 inside the shard_map body).  The
    single place the slice-and-rewrap wiring lives, so every shard_map
    call site stays in sync when the layout grows fields.
    """
    return NodeBlocking(
        u_local=u_local[0], other=other[0], weight=weight[0], deg=deg[0],
        block_n=block_n, block_e=block_e,
        chunks_per_block=chunks_per_block, num_nodes=num_nodes)


def build_sharded_node_blocking(src, dst, weight, num_nodes: int,
                                num_shards: int,
                                *, block_n: int = 512, block_e: int = 128,
                                snap_chunks: bool = True
                                ) -> ShardedNodeBlocking:
    """Host-side per-shard node blockings of a mesh-padded edge buffer.

    ``len(src)`` must divide evenly by ``num_shards`` (pad the buffer
    with :func:`repro.core.distributed.pad_edges_for_mesh` first); shard
    ``s`` owns the ``s``-th contiguous slice, matching how a
    ``P(edge_axes)`` sharding splits the same buffer on the mesh.  The
    chunk count is resolved ONCE across shards (max bucket anywhere,
    pow2-snapped), so an all-padding shard still materializes the shared
    layout — all zero weights and zero degrees — instead of a
    shape-mismatched empty one.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    e = src.shape[0]
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if e % num_shards != 0:
        raise ValueError(
            f"edge buffer ({e}) does not divide into {num_shards} shards;"
            " pad with distributed.pad_edges_for_mesh first")
    per = e // num_shards
    nb = max((num_nodes + block_n - 1) // block_n, 1)
    n_pad = nb * block_n
    shards = [
        _block_sorted_half_edges(
            src[s * per:(s + 1) * per], dst[s * per:(s + 1) * per],
            weight[s * per:(s + 1) * per], block_n, nb)
        for s in range(num_shards)
    ]
    # ONE chunk count for every shard: shard_map needs identical static
    # shapes per device, and snapping to the worst shard keeps the
    # compile key stable under admission-time edge balance wobble.
    c = _chunks_for_counts(
        np.stack([counts for _, _, _, counts in shards]).reshape(-1),
        block_e, snap_chunks)
    ul = np.zeros((num_shards, nb, c * block_e), np.int32)
    ot = np.zeros((num_shards, nb, c * block_e), np.int32)
    wt = np.zeros((num_shards, nb, c * block_e), np.float32)
    deg = np.zeros((num_shards, n_pad), np.float32)
    for s, (u, o, w2, counts) in enumerate(shards):
        ul[s], ot[s], wt[s] = _fill_buckets(u, o, w2, counts, nb, c,
                                            block_n, block_e)
        deg[s] = _weighted_degrees(
            src[s * per:(s + 1) * per], dst[s * per:(s + 1) * per],
            weight[s * per:(s + 1) * per], n_pad)
    return ShardedNodeBlocking(
        u_local=jnp.asarray(ul.reshape(num_shards, -1)),
        other=jnp.asarray(ot.reshape(num_shards, -1)),
        weight=jnp.asarray(wt.reshape(num_shards, -1)),
        deg=jnp.asarray(deg),
        block_n=block_n,
        block_e=block_e,
        chunks_per_block=c,
        num_nodes=int(num_nodes),
        num_shards=int(num_shards),
    )


@functools.partial(jax.jit, static_argnames=(
    "block_n", "block_e", "chunks_per_block", "interpret"))
def _edge_spmm_blocked(u_local, other, weight, deg, v, ab,
                       *, block_n: int, block_e: int,
                       chunks_per_block: int, interpret: bool):
    n, k = v.shape
    n_pad = deg.shape[0]
    pad_k = (-k) % 128
    vp = jnp.pad(v.astype(jnp.float32), ((0, n_pad - n), (0, pad_k)))
    gathered = vp[other]  # (NB*C*BE, kp) XLA gather; the scatter is MXU
    out = kernel.edge_spmm_nb(
        u_local, weight, gathered, deg, vp, ab,
        block_n=block_n, block_e=block_e,
        chunks_per_block=chunks_per_block, interpret=interpret)
    return out[:n, :k]


def edge_spmm_blocked(nb: NodeBlocking, v: jax.Array,
                      alpha=1.0, beta=0.0,
                      *, interpret: bool = False) -> jax.Array:
    """alpha * (L V) + beta * V via the node-blocked kernel.

    Accepts (n,) or (n, k) with n == nb.num_nodes; alpha/beta may be
    traced scalars (the streaming service's per-session dilation scale).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    if v.shape[0] != nb.num_nodes:
        raise ValueError(
            f"panel rows {v.shape[0]} != blocking num_nodes {nb.num_nodes}")
    out = _edge_spmm_blocked(
        nb.u_local, nb.other, nb.weight, nb.deg, v, _ab(alpha, beta),
        block_n=nb.block_n, block_e=nb.block_e,
        chunks_per_block=nb.chunks_per_block, interpret=interpret)
    return out[:, 0] if squeeze else out
