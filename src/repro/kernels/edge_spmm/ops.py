"""jit'd wrappers for the edge_spmm kernels.

``edge_spmm`` pads edges to block multiples (zero weight => no
contribution) and lane-aligns the panel; it holds the full (n, k) panel
plus a (block_e, n) one-hot in VMEM, so the backend layer only selects
it up to ``repro.core.backend.ONE_HOT_NODE_LIMIT`` (4096) nodes.

``build_node_blocking`` + ``edge_spmm_blocked`` are the scalable path:
edges are expanded host-side into directed half-edges (u <- o, w) and
bucketed by the node-block of the destination u into a CSR-style
VARIABLE-chunks-per-block layout: each block owns ceil(bucket / BE)
chunks (min 1), a flat chunk->block index map steers the kernel's
scalar-prefetched BlockSpecs, and only the TOTAL chunk count is
pow2-snapped so graphs of similar size share one compiled program (the
streaming store's capacity-class economics) without paying the old
uniform blocks x max-chunks padding on skewed degree distributions.
The kernel then works on (block_n, k) panel slices only — see
kernel.py.  ``build_model_sharded_blocking`` splits the same layout by
DESTINATION node range so each mesh shard owns its panel rows' output
outright (the panel-sharding convention of ``core.distributed``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_spmm import kernel


def _ab(alpha, beta) -> jax.Array:
    a = jnp.asarray(alpha, jnp.float32).reshape(())
    b = jnp.asarray(beta, jnp.float32).reshape(())
    return jnp.stack([a, b])


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array, v: jax.Array,
              alpha=1.0, beta=0.0,
              *, block_e: int = 128, interpret: bool = False) -> jax.Array:
    """alpha * (sum_e w_e x_e x_e^T V) + beta * V; default plain matvec.

    Accepts (n,) or (n, k) panels (1-D round-trips through a column).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    e = src.shape[0]
    n, k = v.shape
    # an edgeless input still needs one (inert) block: a zero-size grid
    # is invalid, and the segment backend returns zeros there
    pad_e = block_e if e == 0 else (-e) % block_e
    if pad_e:
        src = jnp.concatenate([src, jnp.zeros((pad_e,), src.dtype)])
        dst = jnp.concatenate([dst, jnp.ones((pad_e,), dst.dtype)])
        w = jnp.concatenate([w, jnp.zeros((pad_e,), w.dtype)])
    pad_k = (-k) % 128
    pad_n = (-n) % 8  # sublane alignment
    vp = jnp.pad(v.astype(jnp.float32), ((0, pad_n), (0, pad_k)))
    out = kernel.edge_spmm(src, dst, w.astype(jnp.float32), vp,
                           _ab(alpha, beta),
                           block_e=block_e, interpret=interpret)
    out = out[:n, :k]
    return out[:, 0] if squeeze else out


class NodeBlocking(NamedTuple):
    """Node-blocked half-edge layout for ``edge_spmm_blocked``.

    Built host-side ONCE per graph (or per capacity-class admission in
    the streaming graph store) and cached alongside the padded edge
    buffers; every matvec/fused-series-step reuses it.  Arrays are
    device-resident; the ints are static and part of the compile key.

    The chunk layout is CSR-style: block b owns ``ceil(bucket_b / BE)``
    chunks (min 1 so every block is initialized), laid out contiguously
    in block order; ``chunk_block`` maps chunk -> block for the kernel's
    scalar-prefetched BlockSpecs.  Padding chunks (total snapped to a
    power of two) extend the LAST block's run with zero weights, so no
    block's init/epilogue ever re-fires.
    """

    u_local: jax.Array  # (NC*BE,) int32 — dest index local to its block
    other: jax.Array  # (NC*BE,) int32 — global source node per half-edge
    weight: jax.Array  # (NC*BE,) float32 — 0 => padding slot
    chunk_block: jax.Array  # (NC+1,) int32 — block per chunk + tail sentinel
    deg: jax.Array  # (NB*block_n,) float32 — weighted degrees, row-padded
    block_n: int  # nodes per block (static)
    block_e: int  # half-edges per kernel chunk (static)
    num_chunks: int  # NC, TOTAL chunks (static, pow2-snapped)
    num_nodes: int  # real node count n (static); NB = ceil(n / block_n)

    @property
    def num_blocks(self) -> int:
        return self.deg.shape[0] // self.block_n

    @property
    def padded_nodes(self) -> int:
        return self.deg.shape[0]

    @property
    def padded_half_edges(self) -> int:
        """Half-edge SLOTS the kernel walks (live + padding) — the work
        metric the skew benchmarks compare against the uniform layout."""
        return self.num_chunks * self.block_e


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1) — shared by the blocking's
    chunk snapping here and the service's occupancy buckets."""
    return 1 << max(int(np.ceil(np.log2(max(int(x), 1)))), 0)


def _block_sorted_half_edges(src, dst, weight, block_n: int, nb: int):
    """Live edges -> directed half-edges sorted by destination node-block.

    Returns (u, o, w2, counts): half-edge destination/source/weight in
    deterministic block order plus per-block half-edge counts.  Shared
    by the single-device and per-shard blocking builders so both lay
    half-edges out identically.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    live = weight != 0.0
    src, dst, weight = src[live], dst[live], weight[live]
    # directed half-edges: destination u, source o
    u = np.concatenate([src, dst])
    o = np.concatenate([dst, src])
    w2 = np.concatenate([weight, weight])
    blk = u // block_n
    order = np.argsort(blk, kind="stable")  # deterministic layout
    counts = np.bincount(blk[order], minlength=nb)
    return u[order], o[order], w2[order], counts


def uniform_chunks_for_counts(counts, block_e: int,
                              snap_chunks: bool = True) -> int:
    """Chunks per block under the LEGACY uniform layout (every block
    pays the worst bucket, pow2-snapped).  Kept as the comparison
    baseline for the skew benchmarks and property tests."""
    c = max(int(np.ceil(counts.max(initial=0) / block_e)), 1)
    return next_pow2(c) if snap_chunks else c


def uniform_padded_half_edges(counts, block_e: int,
                              snap_chunks: bool = True) -> int:
    """Half-edge slots the legacy uniform layout would walk:
    num_blocks * max-chunks * block_e."""
    nb = int(np.asarray(counts).shape[0])
    return nb * uniform_chunks_for_counts(counts, block_e, snap_chunks) \
        * block_e


def _chunk_counts(counts, block_e: int):
    """Per-block chunk counts: ceil(bucket / BE), min 1 so every block
    gets its init/epilogue pass even when it holds no live half-edges."""
    counts = np.asarray(counts, np.int64)
    return np.maximum((counts + block_e - 1) // block_e, 1)


def _fill_chunked(u, o, w2, counts, nb: int, nc: int,
                  block_n: int, block_e: int):
    """Scatter block-sorted half-edges into the CSR chunk layout.

    Returns (u_local, other, weight, chunk_block) with flat (nc*BE,)
    half-edge arrays and the (nc+1,) chunk->block map; unfilled slots
    stay zero-weight (inert) and padding chunks extend the last block's
    run (sentinel tail included).
    """
    cb_counts = _chunk_counts(counts, block_e)
    chunk_off = np.concatenate([[0], np.cumsum(cb_counts)])
    nc_raw = int(chunk_off[-1])
    assert nc >= nc_raw, (nc, nc_raw)
    ul = np.zeros((nc * block_e,), np.int32)
    ot = np.zeros((nc * block_e,), np.int32)
    wt = np.zeros((nc * block_e,), np.float32)
    total = u.shape[0]
    if total:
        offs = np.concatenate([[0], np.cumsum(counts)])
        blk_of = np.repeat(np.arange(nb, dtype=np.int64), counts)
        within = np.arange(total, dtype=np.int64) - offs[blk_of]
        slot = chunk_off[blk_of] * block_e + within
        ul[slot] = (u - blk_of * block_n).astype(np.int32)
        ot[slot] = o.astype(np.int32)
        wt[slot] = w2
    chunk_block = np.full((nc + 1,), nb - 1, np.int32)
    chunk_block[:nc_raw] = np.repeat(
        np.arange(nb, dtype=np.int32), cb_counts)
    return ul, ot, wt, chunk_block


def _weighted_degrees(src, dst, weight, n_pad: int):
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    live = weight != 0.0
    deg = np.zeros((n_pad,), np.float32)
    np.add.at(deg, src[live], weight[live])
    np.add.at(deg, dst[live], weight[live])
    return deg


def build_node_blocking(src, dst, weight, num_nodes: int,
                        *, block_n: int = 512, block_e: int = 128,
                        snap_chunks: bool = True) -> NodeBlocking:
    """Host-side (numpy) bucketing of edges by destination node-block.

    Each undirected edge (s, d, w) becomes two half-edges — out[s] takes
    +w*(v[s]-v[d]), out[d] takes +w*(v[d]-v[s]) — and L v = deg*v - A v
    lets the kernel carry the v[u] part as a precomputed degree, so a
    half-edge only records (u_local, other, w).  Zero-weight slots
    (capacity padding in the streaming store) are DROPPED here: they are
    inert anyway, and keeping them would pile the entire padding into
    node-block 0.  Blocks own ceil(bucket / block_e) chunks each
    (CSR-style; min 1), and only the TOTAL chunk count is pow2-snapped
    (`snap_chunks`) so the compile key — and therefore the
    compiled-program count — stays logarithmic in graph size while
    skewed buckets no longer inflate every other block's padding.
    """
    nb = max((num_nodes + block_n - 1) // block_n, 1)
    n_pad = nb * block_n
    u, o, w2, counts = _block_sorted_half_edges(src, dst, weight,
                                                block_n, nb)
    nc_raw = int(_chunk_counts(counts, block_e).sum())
    nc = next_pow2(nc_raw) if snap_chunks else nc_raw
    ul, ot, wt, cb = _fill_chunked(u, o, w2, counts, nb, nc,
                                   block_n, block_e)
    deg = _weighted_degrees(src, dst, weight, n_pad)
    return NodeBlocking(
        u_local=jnp.asarray(ul),
        other=jnp.asarray(ot),
        weight=jnp.asarray(wt),
        chunk_block=jnp.asarray(cb),
        deg=jnp.asarray(deg),
        block_n=block_n,
        block_e=block_e,
        num_chunks=nc,
        num_nodes=int(num_nodes),
    )


class ShardedNodeBlocking(NamedTuple):
    """Per-shard node-blocked half-edge layouts for mesh-parallel matvecs.

    The edge buffer is split into ``num_shards`` contiguous slices (the
    :func:`repro.core.distributed.pad_edges_for_mesh` contract) and each
    slice is bucketed INDEPENDENTLY by destination node-block, exactly
    like :func:`build_node_blocking` does for the whole buffer.  All
    shards share ONE static layout — the chunk count is pow2-snapped to
    the worst shard — so the stacked arrays drop straight into a
    ``shard_map`` with the shard axis partitioned over the mesh's edge
    axes, and every shard compiles against identical shapes.

    The matvec decomposes per shard as ``L_s v = deg_s * v - A_s v``
    with ``deg_s`` the weighted degrees of THAT SHARD's edges only, so
    the one psum of the (n, k) panel reconstructs
    ``sum_s L_s v = L v`` exactly (no double-counted diagonal).  A shard
    whose slice holds zero live edges (all capacity padding) gets an
    all-zero layout in the same shapes: its kernel output is exactly
    zero and the psum is unaffected.
    """

    u_local: jax.Array  # (S, NC*BE) int32 — dest index local to block
    other: jax.Array  # (S, NC*BE) int32 — global source node
    weight: jax.Array  # (S, NC*BE) float32 — 0 => padding slot
    chunk_block: jax.Array  # (S, NC+1) int32 — per-shard chunk->block map
    deg: jax.Array  # (S, NB*block_n) float32 — PER-SHARD weighted degrees
    block_n: int  # static
    block_e: int  # static
    num_chunks: int  # NC, TOTAL chunks, shared across shards (static)
    num_nodes: int  # real node count n (static)
    num_shards: int  # S (static)

    @property
    def num_blocks(self) -> int:
        return self.deg.shape[1] // self.block_n

    @property
    def padded_nodes(self) -> int:
        return self.deg.shape[1]

    def shard(self, s: int) -> NodeBlocking:
        """Single-shard view — what one mesh device computes with."""
        return NodeBlocking(
            u_local=self.u_local[s], other=self.other[s],
            weight=self.weight[s], chunk_block=self.chunk_block[s],
            deg=self.deg[s],
            block_n=self.block_n, block_e=self.block_e,
            num_chunks=self.num_chunks,
            num_nodes=self.num_nodes)

    @property
    def statics(self) -> dict:
        """The compile-key statics, as kwargs for
        :func:`shard_local_blocking` (and tick-program builders)."""
        return dict(block_n=self.block_n, block_e=self.block_e,
                    num_chunks=self.num_chunks,
                    num_nodes=self.num_nodes)


def shard_local_blocking(u_local, other, weight, chunk_block, deg,
                         *, block_n: int, block_e: int, num_chunks: int,
                         num_nodes: int) -> NodeBlocking:
    """One device's NodeBlocking from shard_map-LOCAL slices of a
    :class:`ShardedNodeBlocking`'s stacked arrays (the leading shard
    axis is partitioned down to size 1 inside the shard_map body).  The
    single place the slice-and-rewrap wiring lives, so every shard_map
    call site stays in sync when the layout grows fields.
    """
    return NodeBlocking(
        u_local=u_local[0], other=other[0], weight=weight[0],
        chunk_block=chunk_block[0], deg=deg[0],
        block_n=block_n, block_e=block_e,
        num_chunks=num_chunks, num_nodes=num_nodes)


def build_sharded_node_blocking(src, dst, weight, num_nodes: int,
                                num_shards: int,
                                *, block_n: int = 512, block_e: int = 128,
                                snap_chunks: bool = True
                                ) -> ShardedNodeBlocking:
    """Host-side per-shard node blockings of a mesh-padded edge buffer.

    ``len(src)`` must divide evenly by ``num_shards`` (pad the buffer
    with :func:`repro.core.distributed.pad_edges_for_mesh` first); shard
    ``s`` owns the ``s``-th contiguous slice, matching how a
    ``P(edge_axes)`` sharding splits the same buffer on the mesh.  The
    chunk count is resolved ONCE across shards (max bucket anywhere,
    pow2-snapped), so an all-padding shard still materializes the shared
    layout — all zero weights and zero degrees — instead of a
    shape-mismatched empty one.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    e = src.shape[0]
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if e % num_shards != 0:
        raise ValueError(
            f"edge buffer ({e}) does not divide into {num_shards} shards;"
            " pad with distributed.pad_edges_for_mesh first")
    per = e // num_shards
    nb = max((num_nodes + block_n - 1) // block_n, 1)
    n_pad = nb * block_n
    shards = [
        _block_sorted_half_edges(
            src[s * per:(s + 1) * per], dst[s * per:(s + 1) * per],
            weight[s * per:(s + 1) * per], block_n, nb)
        for s in range(num_shards)
    ]
    # ONE total chunk count for every shard: shard_map needs identical
    # static shapes per device, and snapping to the worst shard keeps
    # the compile key stable under admission-time edge balance wobble.
    nc_raw = max(int(_chunk_counts(counts, block_e).sum())
                 for _, _, _, counts in shards)
    nc = next_pow2(nc_raw) if snap_chunks else nc_raw
    ul = np.zeros((num_shards, nc * block_e), np.int32)
    ot = np.zeros((num_shards, nc * block_e), np.int32)
    wt = np.zeros((num_shards, nc * block_e), np.float32)
    cb = np.zeros((num_shards, nc + 1), np.int32)
    deg = np.zeros((num_shards, n_pad), np.float32)
    for s, (u, o, w2, counts) in enumerate(shards):
        ul[s], ot[s], wt[s], cb[s] = _fill_chunked(
            u, o, w2, counts, nb, nc, block_n, block_e)
        deg[s] = _weighted_degrees(
            src[s * per:(s + 1) * per], dst[s * per:(s + 1) * per],
            weight[s * per:(s + 1) * per], n_pad)
    return ShardedNodeBlocking(
        u_local=jnp.asarray(ul),
        other=jnp.asarray(ot),
        weight=jnp.asarray(wt),
        chunk_block=jnp.asarray(cb),
        deg=jnp.asarray(deg),
        block_n=block_n,
        block_e=block_e,
        num_chunks=nc,
        num_nodes=int(num_nodes),
        num_shards=int(num_shards),
    )


class ModelShardedBlocking(NamedTuple):
    """DESTINATION-aligned per-shard chunk layouts for panel sharding.

    Where :class:`ShardedNodeBlocking` splits the EDGE BUFFER (each
    shard sees every node, outputs partial sums, and a psum adds them),
    this splits the NODE RANGE: shard ``s`` owns panel rows
    ``[s * R, (s + 1) * R)`` and receives ALL half-edges destined to
    those rows.  Its local matvec output rows are therefore FINAL — no
    cross-shard summation — which is what lets a solver step (a) fuse
    the dilation AXPY back into the kernel epilogue per shard, (b)
    compute its mu-EG gram contribution on local rows only, and (c)
    ship rows + gram in ONE fused collective (see
    ``core.program.build_tick_model_sharded``).  Skew is absorbed by
    the CSR chunk layout: a shard owning hub nodes simply has more live
    chunks, and the shared pow2-snapped total keeps shapes identical
    across shards (a hub shard pads less, a sparse shard pads more).

    ``u_local``/``chunk_block`` are local to the shard's own blocks;
    ``other`` stays GLOBAL (sources live anywhere), and ``deg`` holds
    the FULL weighted degrees of the shard's rows (rows are owned
    outright, so no per-shard degree splitting).
    """

    u_local: jax.Array  # (S, NC*BE) int32 — dest local to its block
    other: jax.Array  # (S, NC*BE) int32 — GLOBAL source node
    weight: jax.Array  # (S, NC*BE) float32 — 0 => padding slot
    chunk_block: jax.Array  # (S, NC+1) int32 — SHARD-local block map
    deg: jax.Array  # (S, R) float32 — full degrees of the shard's rows
    block_n: int  # static
    block_e: int  # static
    num_chunks: int  # NC, shared across shards (static, pow2-snapped)
    num_nodes: int  # real node count n (static)
    num_shards: int  # S (static)

    @property
    def rows_per_shard(self) -> int:
        return self.deg.shape[1]

    @property
    def padded_nodes(self) -> int:
        return self.num_shards * self.deg.shape[1]

    @property
    def num_blocks(self) -> int:
        """Blocks per shard."""
        return self.deg.shape[1] // self.block_n

    @property
    def padded_half_edges(self) -> int:
        """Total half-edge slots across shards."""
        return self.num_shards * self.num_chunks * self.block_e

    def shard(self, s: int) -> NodeBlocking:
        """Single-shard view in the shard's LOCAL node coordinates."""
        return NodeBlocking(
            u_local=self.u_local[s], other=self.other[s],
            weight=self.weight[s], chunk_block=self.chunk_block[s],
            deg=self.deg[s],
            block_n=self.block_n, block_e=self.block_e,
            num_chunks=self.num_chunks, num_nodes=self.rows_per_shard)

    @property
    def statics(self) -> dict:
        """Compile-key statics for the model-sharded tick builders."""
        return dict(block_n=self.block_n, block_e=self.block_e,
                    num_chunks=self.num_chunks, num_nodes=self.num_nodes,
                    num_shards=self.num_shards)


def build_model_sharded_blocking(src, dst, weight, num_nodes: int,
                                 num_shards: int,
                                 *, block_n: int = 512, block_e: int = 128,
                                 snap_chunks: bool = True
                                 ) -> ModelShardedBlocking:
    """Host-side destination-aligned chunk layouts for panel sharding.

    Node-blocks are padded to a multiple of ``num_shards`` and assigned
    contiguously (shard ``s`` owns blocks ``[s * NBs, (s + 1) * NBs)``,
    i.e. rows ``[s * R, (s + 1) * R)``); every live half-edge lands on
    the shard owning its DESTINATION row.  Unlike the edge-sharded
    builder there is no per-shard edge-buffer slicing contract: any
    (src, dst, weight) buffer works, zero-weight slots are dropped.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    nb_real = max((num_nodes + block_n - 1) // block_n, 1)
    nb_per = (nb_real + num_shards - 1) // num_shards
    nb_total = nb_per * num_shards
    rows = nb_per * block_n
    n_pad = nb_total * block_n
    u, o, w2, counts = _block_sorted_half_edges(src, dst, weight,
                                                block_n, nb_total)
    deg_full = _weighted_degrees(src, dst, weight, n_pad)
    offs = np.concatenate([[0], np.cumsum(counts)])
    # shared pow2-snapped total chunk count: identical static shapes per
    # shard; a hub-heavy shard uses more live chunks, not a new shape
    nc_raw = max(
        int(_chunk_counts(counts[s * nb_per:(s + 1) * nb_per],
                          block_e).sum())
        for s in range(num_shards))
    nc = next_pow2(nc_raw) if snap_chunks else nc_raw
    ul = np.zeros((num_shards, nc * block_e), np.int32)
    ot = np.zeros((num_shards, nc * block_e), np.int32)
    wt = np.zeros((num_shards, nc * block_e), np.float32)
    cb = np.zeros((num_shards, nc + 1), np.int32)
    deg = np.zeros((num_shards, rows), np.float32)
    for s in range(num_shards):
        lo, hi = offs[s * nb_per], offs[(s + 1) * nb_per]
        ul[s], ot[s], wt[s], cb[s] = _fill_chunked(
            u[lo:hi] - s * rows,  # shard-local node coordinates
            o[lo:hi], w2[lo:hi],
            counts[s * nb_per:(s + 1) * nb_per],
            nb_per, nc, block_n, block_e)
        deg[s] = deg_full[s * rows:(s + 1) * rows]
    return ModelShardedBlocking(
        u_local=jnp.asarray(ul),
        other=jnp.asarray(ot),
        weight=jnp.asarray(wt),
        chunk_block=jnp.asarray(cb),
        deg=jnp.asarray(deg),
        block_n=block_n,
        block_e=block_e,
        num_chunks=nc,
        num_nodes=int(num_nodes),
        num_shards=int(num_shards),
    )


def model_shard_local_blocking(u_local, other, weight, chunk_block, deg,
                               *, block_n: int, block_e: int,
                               num_chunks: int, num_nodes: int,
                               num_shards: int) -> NodeBlocking:
    """One device's local-coordinate NodeBlocking from shard_map-LOCAL
    slices of a :class:`ModelShardedBlocking` (leading shard axis
    partitioned down to size 1).  ``num_nodes`` of the result is the
    shard's ROW count, not the global n."""
    del num_nodes, num_shards  # statics travel for key symmetry only
    return NodeBlocking(
        u_local=u_local[0], other=other[0], weight=weight[0],
        chunk_block=chunk_block[0], deg=deg[0],
        block_n=block_n, block_e=block_e,
        num_chunks=num_chunks, num_nodes=deg.shape[1])


@functools.partial(jax.jit, static_argnames=(
    "block_n", "block_e", "num_chunks", "padded_nodes", "use_kernel",
    "interpret"))
def model_local_rows(u_local, other, weight, chunk_block, deg,
                     v: jax.Array, ab: jax.Array, row_start,
                     *, block_n: int, block_e: int, num_chunks: int,
                     padded_nodes: int, use_kernel: bool,
                     interpret: bool = False):
    """This shard's (R, k) OWNED rows of alpha * (L V) + beta * V.

    ``v`` is the FULL replicated (n, k) panel (sources live anywhere);
    the layout arrays are one shard's slices of a
    :class:`ModelShardedBlocking` (shard-local coordinates);
    ``row_start`` is the (traced) first global row this shard owns.
    Rows are final — the caller's collective across shards merely
    assembles disjoint row ranges, it never sums overlapping
    contributions — so the affine epilogue fuses HERE, per shard, not
    post-collective.  ``use_kernel`` picks the Pallas chunk kernel vs
    the segment-sum form (same layout arrays either way).
    """
    n, k = v.shape
    rows = deg.shape[0]
    v = v.astype(jnp.float32)
    if use_kernel:
        pad_k = (-k) % 128
        vp = jnp.pad(v, ((0, padded_nodes - n), (0, pad_k)))
        gathered = vp[other]  # (NC*BE, kp) global gather
        v_rows = jax.lax.dynamic_slice(
            vp, (row_start, 0), (rows, k + pad_k))
        out = kernel.edge_spmm_nb(
            u_local, weight, gathered, chunk_block, deg, v_rows, ab,
            block_n=block_n, block_e=block_e,
            num_chunks=num_chunks, interpret=interpret)
        return out[:, :k]
    vp = jnp.pad(v, ((0, padded_nodes - n), (0, 0)))
    blk = jnp.repeat(chunk_block[:num_chunks], block_e)
    dest = blk * block_n + u_local  # shard-local row ids
    contrib = weight[:, None] * vp[other]
    av = jnp.zeros((rows, k), jnp.float32).at[dest].add(contrib)
    v_rows = jax.lax.dynamic_slice(vp, (row_start, 0), (rows, k))
    out = deg[:, None] * v_rows - av
    return ab[0] * out + ab[1] * v_rows


@functools.partial(jax.jit, static_argnames=(
    "block_n", "block_e", "num_chunks", "interpret"))
def _edge_spmm_blocked(u_local, other, weight, chunk_block, deg, v, ab,
                       *, block_n: int, block_e: int,
                       num_chunks: int, interpret: bool):
    n, k = v.shape
    n_pad = deg.shape[0]
    pad_k = (-k) % 128
    vp = jnp.pad(v.astype(jnp.float32), ((0, n_pad - n), (0, pad_k)))
    gathered = vp[other]  # (NC*BE, kp) XLA gather; the scatter is MXU
    out = kernel.edge_spmm_nb(
        u_local, weight, gathered, chunk_block, deg, vp, ab,
        block_n=block_n, block_e=block_e,
        num_chunks=num_chunks, interpret=interpret)
    return out[:n, :k]


def edge_spmm_blocked(nb: NodeBlocking, v: jax.Array,
                      alpha=1.0, beta=0.0,
                      *, interpret: bool = False) -> jax.Array:
    """alpha * (L V) + beta * V via the node-blocked kernel.

    Accepts (n,) or (n, k) with n == nb.num_nodes; alpha/beta may be
    traced scalars (the streaming service's per-session dilation scale).
    """
    squeeze = v.ndim == 1
    if squeeze:
        v = v[:, None]
    if v.shape[0] != nb.num_nodes:
        raise ValueError(
            f"panel rows {v.shape[0]} != blocking num_nodes {nb.num_nodes}")
    out = _edge_spmm_blocked(
        nb.u_local, nb.other, nb.weight, nb.chunk_block, nb.deg, v,
        _ab(alpha, beta),
        block_n=nb.block_n, block_e=nb.block_e,
        num_chunks=nb.num_chunks, interpret=interpret)
    return out[:, 0] if squeeze else out
