"""edge_spmm Pallas kernel package."""
from repro.kernels.edge_spmm import ops, ref  # noqa: F401
