"""Pure-jnp oracle for edge_spmm."""
import jax
import jax.numpy as jnp


def edge_spmm(src: jax.Array, dst: jax.Array, w: jax.Array,
              v: jax.Array) -> jax.Array:
    """Y = sum_e w_e x_e (x_e^T V) via scatter-add (the GPU-style form)."""
    diff = v[src] - v[dst]
    wd = w[:, None] * diff
    out = jnp.zeros_like(v)
    out = out.at[src].add(wd)
    out = out.at[dst].add(-wd)
    return out


def edge_spmm_affine(src: jax.Array, dst: jax.Array, w: jax.Array,
                     v: jax.Array, alpha, beta) -> jax.Array:
    """alpha * (L V) + beta * V — oracle for the fused affine epilogue
    (both the one-hot and the node-blocked kernel variants)."""
    return alpha * edge_spmm(src, dst, w, v) + beta * v
