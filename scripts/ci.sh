#!/usr/bin/env bash
# Fast CI lane: everything except the `slow`-marked system/train suites.
# Full tier-1 verify remains `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
