#!/usr/bin/env bash
# Fast CI lane: everything except the `slow`-marked system/train suites.
# Full tier-1 verify remains `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Stochastic probing suite first (fixed PRNG seeds — deterministic, and
# cheap): a regression in the spectral probes invalidates every
# downstream auto-tuned result, so fail fast on it.
python -m pytest -q -m "stochastic and not slow"
# Kernel/backend equivalence next (interpret-mode pallas == segment):
# a kernel regression silently corrupts every pallas-backend solve.
python -m pytest -q -m "pallas and not slow"
# Distributed lane: a SUBPROCESS with 8 virtual CPU devices (the flag
# must be set before jax initializes, hence the fresh interpreter) so
# the shard_map collectives — per-shard matvecs, psum'd series
# programs, sharded capacity-class ticks — actually cross device
# boundaries instead of degenerating to a 1x1 mesh.
# (forced flag LAST: XLA parses duplicate flags last-wins, so an
# inherited device-count flag must not override the lane's 8)
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python -m pytest -q -m "distributed and not slow"
python -m pytest -q -m "not slow and not stochastic and not pallas and not distributed" "$@"
# Serving smoke (BLOCKING): boot `python -m repro.serve` as a real
# subprocess, drive a short HTTP load through admit/push/labels/summary,
# assert a sane p99 and a clean SIGTERM shutdown — the process-level
# contract no in-process test exercises.
python -m benchmarks.bench_serve --http-smoke
# Perf-trajectory gate (BLOCKING for stream,serve): re-run the
# streaming + serving benches and diff their freshly written
# BENCH_*.json key metrics against the committed files.  These two
# lanes have been regression-quiet across PRs 6-9, so a >25% drop (or
# a crashed bench module) now fails CI.  Rows with committed
# us_per_call=0 are exempt by design: interpret-mode pallas rows
# (CPU kernel emulation, not real timings) and the serve ingest walls
# (thread-interleaving makes even best-of-3 walls bimodal; bench_serve
# gates via its internal correctness asserts instead) — which keeps
# the blocking gate on the stable jit-compute-bound stream numbers.
python -m benchmarks.run --check --only stream,serve
# Skew + weak-scaling rows (NON-BLOCKING): the kernels/distributed
# benches carry the CSR-vs-uniform padded-work rows and the
# fused-collective model-tick rows; their wall numbers spawn device
# subprocesses and are still noisy on shared runners, so regressions
# warn without failing CI.
# run.py exits 2 for a metric regression, 1 for a crashed bench module:
# word the warning accordingly so a broken bench is not mistaken for
# wall-clock noise.
bench_status=0
python -m benchmarks.run --check --only kernels,distributed || bench_status=$?
if [ "$bench_status" -eq 2 ]; then
    echo "[ci] WARNING: kernels/distributed bench --check reported a >25% perf regression (non-blocking)"
elif [ "$bench_status" -ne 0 ]; then
    echo "[ci] WARNING: kernels/distributed bench --check FAILED TO RUN (exit $bench_status) — a bench module crashed (non-blocking)"
fi
