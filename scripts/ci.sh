#!/usr/bin/env bash
# Fast CI lane: everything except the `slow`-marked system/train suites.
# Full tier-1 verify remains `PYTHONPATH=src python -m pytest -x -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Stochastic probing suite first (fixed PRNG seeds — deterministic, and
# cheap): a regression in the spectral probes invalidates every
# downstream auto-tuned result, so fail fast on it.
python -m pytest -q -m "stochastic and not slow"
# Kernel/backend equivalence next (interpret-mode pallas == segment):
# a kernel regression silently corrupts every pallas-backend solve.
python -m pytest -q -m "pallas and not slow"
exec python -m pytest -q -m "not slow and not stochastic and not pallas" "$@"
