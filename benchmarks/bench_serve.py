"""Serving-layer benchmarks: many-tenant ingest throughput with the
double-buffered async pipeline vs the serialized baseline, and
per-request-type latency percentiles under a synthetic load generator.

The headline claim: with many tenants pushing edge batches while the
engine ticks, the double-buffered pipeline — pushes merge into a host
staging buffer and return immediately, one engine thread drains the
swapped buffer between device ticks — sustains higher update throughput
to the SAME residual target than the serialized baseline, where every
push waits its turn for the engine lock behind running ticks
(``ingest_overlap_wall_ratio`` in BENCH_serve.json, wall-clock to
fleet convergence with every batch applied; all ingest walls are
reported, not gated — they are too scheduler-noisy on a shared runner
to block CI on, so the gate takes this bench's internal correctness
asserts and crash-freeness instead).

Latency rows come from the server's own geometric-bucket histograms
(repro.serve.metrics): p50/p99 per request type (admit / push / labels
/ summary / evict) under interleaved query threads.

``python -m benchmarks.bench_serve --http-smoke`` is the CI stage that
boots ``python -m repro.serve`` as a real subprocess, runs a short HTTP
load against it, asserts a sane p99 and a clean SIGTERM shutdown.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import write_bench_json
from repro.core import graphs

TENANTS = 6
N_NODES = 120
ROUNDS = 96  # edge-batch pushes per tenant — enough that the timed
# serialized ingest wall (the gated row) is O(seconds), well clear of
# thread-scheduling jitter
BATCH_EDGES = 8
QUERY_THREADS = 2
QUERIES_PER_THREAD = 40


def _service_cfg():
    from repro.stream.service import ServiceConfig

    return ServiceConfig(k=6, num_clusters=4, degree=9, steps_per_tick=10,
                         lr=0.3, tol=5e-3, dilation_strength=6.0, seed=0)


def _tenant_graph(i: int):
    g, _ = graphs.sbm_graph(N_NODES, 4, p_in=0.3, p_out=0.02, seed=100 + i)
    edges = np.stack([np.asarray(g.src), np.asarray(g.dst)], axis=1)
    return edges, np.asarray(g.weight)


def _tenant_batches(i: int):
    """ROUNDS small intra-community reweight batches per tenant —
    the steady-state streaming workload.  Per-batch deltas stay small
    (2*sum|dw| well under the drift bound) so the serialized baseline's
    individual applies ride the cheap first-order path: the comparison
    measures ingest/tick OVERLAP, not a fallback-resolve storm."""
    rng = np.random.default_rng(1000 + i)
    out = []
    for _ in range(ROUNDS):
        blk = rng.integers(4) * (N_NODES // 4)
        e = np.stack([rng.integers(blk, blk + N_NODES // 4, BATCH_EDGES),
                      rng.integers(blk, blk + N_NODES // 4, BATCH_EDGES)],
                     axis=1)
        e = e[e[:, 0] != e[:, 1]]
        out.append((e, np.full(len(e), 0.01, np.float32)))
    return out


def _drive(pipeline: str, queries: bool):
    """Steady-state many-tenant load: admit TENANTS sessions and run
    them to convergence UNTIMED (tick-program compiles for every pow2
    occupancy bucket happen here, identically for both pipelines), then
    time the streaming phase — every tenant's thread pushes its edge
    batches while the engine re-converges the fleet — until all batches
    are applied and every session is back at the SAME residual target.
    Returns (server, ingest_wall_s, total_updates)."""
    from repro.serve import Server, ServerConfig

    srv = Server(ServerConfig(service=_service_cfg(), pipeline=pipeline,
                              idle_sleep_s=0.001))
    sids = [f"t{i}" for i in range(TENANTS)]
    batches = {sid: _tenant_batches(i) for i, sid in enumerate(sids)}
    srv.start()
    for i, sid in enumerate(sids):
        edges, w = _tenant_graph(i)
        srv.admit(sid, edges, N_NODES, weights=w, num_clusters=4,
                  edge_capacity=2048)
    assert srv.wait_converged(timeout=600.0), "warmup failed to converge"

    def pusher(sid):
        for e, w in batches[sid]:
            srv.push(sid, e, w, mode="add")

    def querier(t):
        rng = np.random.default_rng(2000 + t)
        for _ in range(QUERIES_PER_THREAD):
            sid = sids[rng.integers(TENANTS)]
            srv.summary(sid)
            srv.labels(sid)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=pusher, args=(sid,)) for sid in sids]
    if queries:
        threads += [threading.Thread(target=querier, args=(t,))
                    for t in range(QUERY_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert srv.flush(timeout=300.0), "pipeline failed to drain"
    assert srv.wait_converged(timeout=300.0), "fleet failed to converge"
    wall = time.perf_counter() - t0
    total = sum(len(e) for bs in batches.values() for e, _ in bs)
    assert srv.metrics.counter("dropped_batches") == 0
    return srv, wall, total


def _best_wall(mode: str, reps: int = 3):
    """Best (minimum) ingest wall over ``reps`` identical drives.
    Single walls swing +-30% or worse on shared 1-core runners (thread
    scheduling + background load), which is too noisy for the BLOCKING
    --check gate.  The MINIMUM is the standard stable wall estimator:
    it is bounded below by the actual compute in the drive, so it only
    moves when the code gets slower — exactly what the gate should
    fire on — while medians still carry whatever load the runner
    happened to have.  The first rep also pays any residual
    compilation, so later reps time the steady state."""
    walls = []
    for _ in range(reps):
        srv, wall, total = _drive(mode, queries=False)
        srv.stop()
        walls.append(wall)
    return min(walls), total


def run():
    rows = []
    # -- A/B: serialized baseline vs double-buffered pipeline ----------
    wall_ser, updates = _best_wall("serialized")
    wall_db, _ = _best_wall("double_buffer")
    ups_ser = updates / wall_ser
    ups_db = updates / wall_db
    speedup = wall_ser / wall_db
    # ALL ingest walls here are reported, NOT gated (us_per_call=0;
    # the extra key avoids the gated "speedup" namespace on purpose):
    # even best-of-3 serialized walls are bimodal run to run because
    # thread interleaving changes how many re-convergence ticks the
    # engine runs — the WORK varies, not just the timing — and the
    # double-buffer wall collapsed to the scheduling noise floor once
    # the engine drained whole capacity classes per apply.  What this
    # bench contributes to the BLOCKING stream,serve --check stage is
    # its internal correctness asserts (every batch applied, zero
    # drops, fleet back at tol) and crash-freeness; the gated perf
    # rows live in bench_stream.
    rows.append(("serve/ingest_serialized", 0.0,
                 f"{ups_ser:.0f} updates/s to tol, best of 3, "
                 f"wall_us_per_update={wall_ser / updates * 1e6:.0f}"))
    rows.append(("serve/ingest_double_buffer", 0.0,
                 f"{ups_db:.0f} updates/s to tol, best of 3, "
                 f"wall_us_per_update={wall_db / updates * 1e6:.0f}"))
    rows.append(("serve/ingest_overlap", 0.0,
                 f"{speedup:.2f}x serialized/double_buffer wall"))

    # -- request-latency percentiles under interleaved load ------------
    # us_per_call is 0.0 ON PURPOSE: tail latencies under a loaded
    # engine are dominated by one-time XLA-compilation stalls and
    # runner oversubscription, orders-of-magnitude unstable run to run,
    # so they are reported (derived text + extra["latency"]) but NOT
    # fed to the --check regression gate (which skips rows whose
    # committed us_per_call <= 0).  The same reasoning demotes the
    # ingest walls above.
    srv, _, _ = _drive("double_buffer", queries=True)
    for sid in list(srv.service.session_ids()):
        srv.evict(sid)
    srv.stop()
    snap = srv.stats()
    latency = snap["latency"]
    for op in ("admit", "push", "labels", "summary", "evict"):
        s = latency[op]
        for q in ("p50", "p99"):
            rows.append((f"serve/{op}_{q}", 0.0,
                         f"{s[f'{q}_s'] * 1e6:.0f}us n={s['count']} "
                         f"mean={s['mean_s'] * 1e6:.0f}us"))

    write_bench_json("serve", rows, extra={
        "ingest_overlap_wall_ratio": speedup,
        "serialized_updates_per_s": ups_ser,
        "double_buffer_updates_per_s": ups_db,
        "tenants": TENANTS,
        "updates": updates,
        "latency": latency,
        "counters": snap["counters"],
        "tick_utilization": snap["gauges"].get("tick_utilization", 0.0),
    })
    return rows


# ---------------------------------------------------------------------------
# --http-smoke: boot the real process, load it over HTTP, kill it cleanly
# ---------------------------------------------------------------------------

def http_smoke(p99_budget_s: float = 3.0) -> int:
    import json
    import os
    import signal
    import subprocess
    import sys
    import urllib.request

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    existing = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + existing if existing else "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--num-clusters", "3",
         "--k", "4", "--degree", "7", "--steps-per-tick", "10"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
    try:
        banner = proc.stdout.readline().strip()
        if not banner.startswith("SERVING "):
            print(f"FAIL: bad banner {banner!r}", file=sys.stderr)
            print(proc.stderr.read(), file=sys.stderr)
            return 1
        port = dict(kv.split("=") for kv in banner.split()[1:])["port"]
        base = f"http://127.0.0.1:{port}"

        def req(path, method="GET", body=None):
            data = json.dumps(body).encode() if body is not None else None
            r = urllib.request.Request(
                base + path, data=data, method=method,
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(r, timeout=30) as resp:
                return json.loads(resp.read())

        g, _ = graphs.sbm_graph(60, 3, p_in=0.4, p_out=0.02, seed=0)
        edges = np.stack([np.asarray(g.src), np.asarray(g.dst)], 1).tolist()
        req("/v1/sessions/smoke", "POST",
            {"edges": edges, "num_nodes": 60, "num_clusters": 3,
             "weights": np.asarray(g.weight).tolist()})
        # warm before measuring: wait out the initial convergence (tick
        # programs + probes compile here) and run one labels query (the
        # k-means labeller compiles there) so the gate scores the
        # serving steady state, not one-time jax compilation; with
        # >= 101 samples per type, p99's rank also sits below any
        # single residual straggler
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            if req("/v1/sessions/smoke").get("converged"):
                break
            time.sleep(0.1)
        else:
            print("FAIL: smoke session never converged", file=sys.stderr)
            return 1
        req("/v1/sessions/smoke/labels")
        rng = np.random.default_rng(0)
        for _ in range(110):
            i, j = rng.integers(0, 60, 2)
            if i != j:
                req("/v1/sessions/smoke/edges", "POST",
                    {"edges": [[int(i), int(j)]], "weights": [0.05],
                     "mode": "add"})
            req("/v1/sessions/smoke/labels")
            req("/v1/sessions/smoke")
        metrics = req("/metrics")
        # admit is excluded from the SLO gate: the first request of a
        # cold process pays one-time jax compilation (probes + tick
        # programs), which is provisioning cost, not query latency
        worst = max(
            s["p99_s"] for op, s in metrics["latency"].items()
            if s["count"] and op != "admit")
        print(f"http-smoke: worst non-admit p99 {worst * 1e3:.1f}ms over "
              f"{sum(s['count'] for s in metrics['latency'].values())} "
              f"requests")
        if worst > p99_budget_s:
            print(f"FAIL: p99 {worst:.3f}s > budget {p99_budget_s}s",
                  file=sys.stderr)
            return 1
        proc.send_signal(signal.SIGTERM)
        out, err = proc.communicate(timeout=60)
        if proc.returncode != 0:
            print(f"FAIL: exit code {proc.returncode}\n{err}",
                  file=sys.stderr)
            return 1
        print("http-smoke: clean SIGTERM shutdown (exit 0)")
        return 0
    finally:
        if proc.poll() is None:
            proc.kill()


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--http-smoke", action="store_true",
                    help="subprocess + HTTP load + clean-shutdown gate")
    args = ap.parse_args()
    if args.http_smoke:
        sys.exit(http_smoke())
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
