"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) cell, derive the three roofline terms in
SECONDS on TPU v5e:

    compute    = FLOPs_per_device / 197e12          (bf16 peak per chip)
    memory     = HBM_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9 (per-link ICI)

Sources:
  * collective bytes — parsed from the post-SPMD HLO by the dry-run's
    LOOP-AWARE parser (ops inside scan bodies multiplied by trip count).
  * FLOPs / HBM bytes — ANALYTIC per-step models below.  XLA's
    cost_analysis() counts while-loop bodies ONCE, so for scan-over-
    layers programs it undercounts by ~num_layers x; the dry-run records
    the raw number, and this module computes the corrected per-device
    value from the architecture config (formulas documented inline).
    MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) is reported
    alongside, and the ratio MODEL_FLOPS / HLO_FLOPs flags remat and
    redundancy waste.

Outputs the EXPERIMENTS.md #Roofline table (markdown) and a JSON blob.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES, get_arch

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link


# --------------------------------------------------------------------------
# Analytic per-step cost models (global, then divided by device count)
# --------------------------------------------------------------------------

def _attn_flops_per_token(cfg, s_ctx: int) -> float:
    """Attention score+value FLOPs per token at context s (forward)."""
    if cfg.num_heads == 0:
        return 0.0
    if cfg.use_mla:
        # absorbed decode form ~ h * s * (r + rope) * 2 * 2
        r = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return 2 * 2 * cfg.num_heads * s_ctx * r
    return 2 * 2 * cfg.num_heads * cfg.head_dim * s_ctx


def _ssm_flops_per_token(cfg) -> float:
    if not cfg.ssm_state:
        return 0.0
    d_in = cfg.ssm_expand * cfg.d_model
    # state update + output: 2 * d_in * n * 2
    return 4 * d_in * cfg.ssm_state


def train_flops(cfg, seq: int, batch: int, remat: bool = True) -> dict:
    """Global FLOPs for one training step.

    matmul part: 6 * N_active * tokens (fwd 2 + bwd 4), with remat adding
    one extra forward (factor 8 instead of 6 on the block params).
    attention part: O(s^2) term, fwd+bwd(+remat).
    """
    tokens = seq * batch
    n_act = cfg.active_param_count()
    mat_factor = 8.0 if remat else 6.0
    matmul = mat_factor * n_act * tokens
    attn_layers = _num_attn_layers(cfg)
    attn = (mat_factor / 2) * tokens * (seq / 2) * (
        _attn_flops_per_token(cfg, 1)) * attn_layers / max(cfg.num_layers, 1)
    # _attn_flops_per_token(cfg, 1) is per unit context; average context
    # for causal attention is s/2; scale by fraction of layers with attn
    ssm = (mat_factor / 2) * tokens * _ssm_flops_per_token(cfg) \
        * _num_ssm_layers(cfg)
    model_flops = 6.0 * n_act * tokens
    return {"total": matmul + attn + ssm, "model_flops": model_flops}


def _num_attn_layers(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "encdec":
        return cfg.num_layers * 2 + cfg.encoder_layers
    return cfg.num_layers


def _num_ssm_layers(cfg) -> int:
    if cfg.family == "ssm":
        return cfg.num_layers
    if cfg.family == "hybrid":
        n_g = cfg.num_layers // cfg.attn_every
        return cfg.num_layers - n_g
    return 0


def prefill_flops(cfg, seq: int, batch: int) -> dict:
    tokens = seq * batch
    n_act = cfg.active_param_count()
    matmul = 2.0 * n_act * tokens
    attn = tokens * (seq / 2) * _attn_flops_per_token(cfg, 1) \
        * _num_attn_layers(cfg) / max(cfg.num_layers, 1)
    ssm = tokens * _ssm_flops_per_token(cfg) * _num_ssm_layers(cfg)
    return {"total": matmul + attn + ssm,
            "model_flops": 2.0 * n_act * tokens}


def decode_flops(cfg, s_ctx: int, batch: int) -> dict:
    n_act = cfg.active_param_count()
    matmul = 2.0 * n_act * batch
    attn = batch * _attn_flops_per_token(cfg, s_ctx) \
        * _num_attn_layers(cfg)
    ssm = batch * _ssm_flops_per_token(cfg) * _num_ssm_layers(cfg)
    return {"total": matmul + attn + ssm, "model_flops": 2.0 * n_act * batch}


def decode_hbm_bytes(cfg, s_ctx: int, batch: int) -> float:
    """Decode is memory-bound: every step streams params + the KV cache.
    Serving weights are bf16 (2 bytes); all experts stream at batch 128
    (top-6 of 160 covers nearly every expert)."""
    params = cfg.param_count() * 2.0  # bf16 serving weights
    cache_dt = 1 if cfg.kv_cache_dtype == "int8" else 2
    if cfg.use_mla:
        per_pos = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        cache = cfg.num_layers * batch * s_ctx * per_pos * 2.0
    elif cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nheads = d_in // cfg.ssm_headdim
        cache = cfg.num_layers * batch * nheads * cfg.ssm_headdim \
            * cfg.ssm_state * 4.0
    elif cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        nheads = d_in // cfg.ssm_headdim
        n_attn = cfg.num_layers // cfg.attn_every
        n_ssm = cfg.num_layers - n_attn
        cache = (n_ssm * batch * nheads * cfg.ssm_headdim * cfg.ssm_state
                 * 4.0
                 + n_attn * batch * s_ctx * cfg.num_kv_heads * cfg.head_dim
                 * 2 * cache_dt)
    else:
        cache = cfg.num_layers * batch * s_ctx * cfg.num_kv_heads \
            * cfg.head_dim * 2 * cache_dt
    return params + cache


def train_hbm_bytes(cfg, seq: int, batch: int) -> float:
    """Per-step HBM traffic: params read fwd+bwd+remat-fwd + grads +
    moments r/w + activations w/r (bf16, remat checkpoints only)."""
    n = cfg.param_count()
    params_traffic = 3 * n * 4.0 + n * 4.0  # reads + grad writes
    moments = 4 * n * 4.0  # mu/nu read+write
    tokens = seq * batch
    acts = 2 * tokens * cfg.d_model * 2.0 * cfg.num_layers  # checkpointed
    return params_traffic + moments + acts


def prefill_hbm_bytes(cfg, seq: int, batch: int) -> float:
    n = cfg.param_count()
    tokens = seq * batch
    acts = 2 * tokens * cfg.d_model * 2.0 * max(cfg.num_layers, 1)
    return n * 2.0 + acts


# --------------------------------------------------------------------------
# Assembly
# --------------------------------------------------------------------------

def analyze_cell(rec: dict) -> dict:
    if rec.get("kind") == "sped_step":
        an = rec["analytic"]
        compute_t = an["flops_per_dev"] / PEAK_FLOPS
        memory_t = an["hbm_bytes_per_dev"] / HBM_BW
        coll_t = rec["collectives"]["total_bytes"] / LINK_BW
        terms = {"compute_s": compute_t, "memory_s": memory_t,
                 "collective_s": coll_t}
        bott = max(terms, key=terms.get)
        return {**{k: round(v, 6) for k, v in terms.items()},
                "bottleneck": bott.replace("_s", ""),
                "roofline_fraction": round(
                    compute_t / max(max(terms.values()), 1e-30), 4),
                "model_flops": an["flops_per_dev"],
                "analytic_flops": an["flops_per_dev"],
                "useful_ratio": 1.0,
                "hlo_flops_raw": rec.get("flops") or 0.0,
                "hbm_bytes": an["hbm_bytes_per_dev"],
                "collective_bytes": rec["collectives"]["total_bytes"]}
    cfg = get_arch(rec["arch"])
    sh = SHAPES[rec["shape"]]
    devices = rec.get("devices", 256)
    kind = rec.get("kind", sh["kind"])
    s, b = sh["seq_len"], sh["global_batch"]
    if kind == "train":
        fl = train_flops(cfg, s, b)
        hbm = train_hbm_bytes(cfg, s, b)
    elif kind == "prefill":
        fl = prefill_flops(cfg, s, b)
        hbm = prefill_hbm_bytes(cfg, s, b)
    else:
        fl = decode_flops(cfg, s, b)
        hbm = decode_hbm_bytes(cfg, s, b)
    # analytic totals are GLOBAL; per-device = /devices.  HBM params are
    # sharded so /devices is the right normalization for both terms.
    compute_t = fl["total"] / devices / PEAK_FLOPS
    memory_t = hbm / devices / HBM_BW
    coll_bytes = rec.get("collectives", {}).get("total_bytes", 0)
    coll_t = coll_bytes / LINK_BW  # parser output is already per-device
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    bottleneck = max(terms, key=terms.get)
    step_t = max(terms.values())
    roofline_frac = compute_t / step_t if step_t > 0 else 0.0
    hlo_flops = rec.get("flops") or 0.0
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "roofline_fraction": round(roofline_frac, 4),
        "model_flops": fl["model_flops"],
        "analytic_flops": fl["total"],
        "useful_ratio": round(fl["model_flops"] / fl["total"], 4),
        "hlo_flops_raw": hlo_flops,
        "hbm_bytes": hbm,
        "collective_bytes": coll_bytes,
    }


def suggestion(rec: dict, an: dict) -> str:
    if rec.get("kind") == "sped_step":
        return ("SPED panel psums dominate: see the variant ladder "
                "(cheb degree / fused scatter / bf16 psum)")
    b = an["bottleneck"]
    if b == "compute":
        if an["useful_ratio"] < 0.8:
            return ("compute-bound with remat overhead: move to selective "
                    "checkpointing of only the FFN inputs")
        return "compute-bound at high useful ratio: healthy; raise MXU util"
    if b == "memory":
        if rec.get("kind") == "decode":
            return ("decode streams the KV cache: quantize cache to int8 "
                    "or grow batch to amortize param reads")
        return "memory-bound: fuse elementwise chains, bf16 master weights"
    return ("collective-bound: overlap psum with compute, reduce-scatter "
            "grads instead of all-reduce, or compress the DP payload")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--markdown", default="experiments/roofline.md")
    args = ap.parse_args(argv)

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        # optimized-variant cells carry a filename suffix after the mesh
        # (e.g. __pod_mb4.json): label them so baseline vs optimized rows
        # are distinguishable in the table
        stem = os.path.basename(path)[: -len(".json")]
        parts = stem.split("__")
        if len(parts) >= 3:
            mesh_part = parts[2]
            for m in ("multipod", "pod"):
                if mesh_part.startswith(m) and mesh_part != m:
                    rec["variant"] = mesh_part[len(m) + 1:]
        if rec.get("status") != "ok":
            rows.append({**rec})
            continue
        an = analyze_cell(rec)
        rows.append({**rec, "analysis": an,
                     "next_action": suggestion(rec, an)})

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)

    md = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
          "bottleneck | MODEL/HLO-corr | useful | note |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            md.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} | "
                      f"- | - | - | {r['status']} | - | - | "
                      f"{r.get('reason', r.get('error', ''))[:60]} |")
            continue
        a = r["analysis"]
        mesh_lbl = r['mesh'] + (f" ({r['variant']})" if r.get('variant')
                                else "")
        md.append(
            f"| {r['arch']} | {r['shape']} | {mesh_lbl} | "
            f"{a['compute_s']:.3g} | {a['memory_s']:.3g} | "
            f"{a['collective_s']:.3g} | {a['bottleneck']} | "
            f"{a['model_flops'] / max(a['analytic_flops'], 1):.2f} | "
            f"{a['useful_ratio']:.2f} | {r['next_action'][:70]} |")
    with open(args.markdown, "w") as f:
        f.write("\n".join(md) + "\n")
    print("\n".join(md))


if __name__ == "__main__":
    main()
