"""Spectral probing & dilation-planner benchmark: probe cost vs solver
iterations saved.

For each graph family, three dilation configurations solve the same
bottom-k problem to the same panel-residual tolerance from the same
random init:

  * oracle  — plan_dilation fed the EXACT spectrum (eigh): the best the
              planner's decision rule can do, at zero probe noise.
  * planned — plan_dilation fed the SLQ probe (what production runs).
  * fixed   — the pre-planner repo default: limit_neg_exp(15) scaled by
              strength 8 over the Gershgorin 2*max-degree bound.

Headline claims (tracked in BENCH_spectral.json):
  * planner-tuned dilation reaches tolerance in <= 1.1x the oracle's
    solver iterations on >= 3 families;
  * the fixed config is >= 2x worse than the oracle on >= 1 family;
  * total probe cost (single-vector matvecs) stays < 10% of the
    planned-path solve cost (panel-column matvecs).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import write_bench_json
from repro.core import graphs, operators, solvers
from repro.core.laplacian import laplacian_dense, spectral_radius_upper_bound
from repro.core.series import limit_neg_exp
from repro.spectral import (plan_dilation, probe_from_eigenvalues,
                            probe_graph, series_from_plan)
from repro.stream import warm

K = 6  # eigenvector panel width (trivial + clusters + slack)
BUDGET = 96
TOL = 5e-3
LR = 0.4
CHUNK = 5
MAX_STEPS = 4000
NUM_PROBES = 4
NUM_STEPS = 24
FIXED_DEGREE = 15  # the streaming service's pre-planner defaults
FIXED_STRENGTH = 8.0


def _families():
    return {
        "ring_of_cliques": graphs.ring_of_cliques(6, 20)[0],
        "sbm": graphs.sbm_graph(300, 4, p_in=0.3, p_out=0.05, seed=0)[0],
        "sbm_sparse": graphs.sparse_sbm_graph(
            600, 4, avg_degree_in=8.0, avg_degree_out=2.0, seed=0)[0],
        "three_room_mdp": graphs.three_room_mdp(s=2)[0],
    }


def _iters_to_tol(series, g, key, lr=LR):
    """Solver iterations for one (series, graph) from a fixed init."""
    op = operators.series_operator(series, operators.edge_matvec(g))
    state = solvers.init_state(key, g.num_nodes, K)
    cfg = warm.WarmConfig(tol=TOL, chunk=CHUNK, max_steps=MAX_STEPS, lr=lr)
    t0 = time.perf_counter()
    _, used, res = warm.run_to_tolerance(op, state, cfg)
    return used, float(res), time.perf_counter() - t0


def _plan_dict(plan):
    return {
        "family": plan.family,
        "degree": plan.degree,
        "tau": plan.tau,
        "rho": plan.rho,
        "gamma": plan.gamma,
        "source": plan.source,
    }


def run():
    rows = []
    fam_results = {}
    total_probe_matvecs = 0
    total_solve_matvecs = 0
    key = jax.random.PRNGKey(0)
    for name, g in _families().items():
        lam = np.linalg.eigvalsh(np.asarray(laplacian_dense(g)))
        rho_ub = float(spectral_radius_upper_bound(g))

        oracle_plan = plan_dilation(
            probe_from_eigenvalues(lam), k=K, budget=BUDGET, source="oracle")
        probe = probe_graph(g, key=key, num_probes=NUM_PROBES,
                            num_steps=NUM_STEPS)
        planned_plan = plan_dilation(probe, k=K, budget=BUDGET,
                                     rho_fallback=rho_ub)
        fixed_series = limit_neg_exp(
            FIXED_DEGREE, scale=FIXED_STRENGTH / rho_ub)

        runs = {}
        init_key = jax.random.fold_in(key, g.num_nodes)
        for tag, series, lr in [
            ("oracle", series_from_plan(oracle_plan),
             oracle_plan.suggested_lr(LR)),
            ("planned", series_from_plan(planned_plan),
             planned_plan.suggested_lr(LR)),
            ("fixed", fixed_series, LR),
        ]:
            iters, res, wall = _iters_to_tol(series, g, init_key, lr=lr)
            runs[tag] = {"iters": iters, "residual": res, "wall_s": wall,
                         "converged": res <= TOL}

        # Ratios on iteration counts; the chunked residual check floors
        # counts at CHUNK so 0-iteration warm cases cannot divide by 0.
        base = max(runs["oracle"]["iters"], CHUNK)
        planned_ratio = max(runs["planned"]["iters"], CHUNK) / base
        fixed_ratio = max(runs["fixed"]["iters"], CHUNK) / base
        probe_matvecs = int(probe.num_matvecs)
        solve_matvecs = runs["planned"]["iters"] * planned_plan.degree * K
        total_probe_matvecs += probe_matvecs
        total_solve_matvecs += solve_matvecs

        fam_results[name] = {
            "n": g.num_nodes,
            "num_edges": g.num_edges,
            "k": K,
            "lambda_max_exact": float(lam[-1]),
            "lambda_max_slq": float(probe.lambda_max),
            "rho_gershgorin": rho_ub,
            "plans": {
                "oracle": _plan_dict(oracle_plan),
                "planned": _plan_dict(planned_plan),
                "fixed": {"family": "limit_neg_exp", "degree": FIXED_DEGREE,
                          "tau": FIXED_STRENGTH, "rho": rho_ub,
                          "source": "fixed"},
            },
            "runs": runs,
            "planned_vs_oracle": planned_ratio,
            "fixed_vs_oracle": fixed_ratio,
            "probe_matvecs": probe_matvecs,
            "solve_matvecs_planned": solve_matvecs,
        }
        rows.append((
            f"spectral/{name}_n{g.num_nodes}",
            runs["planned"]["wall_s"] * 1e6,
            f"iters_oracle={runs['oracle']['iters']};"
            f"iters_planned={runs['planned']['iters']};"
            f"iters_fixed={runs['fixed']['iters']};"
            f"planned_vs_oracle={planned_ratio:.2f};"
            f"fixed_vs_oracle={fixed_ratio:.2f}",
        ))

    probe_cost_fraction = total_probe_matvecs / max(total_solve_matvecs, 1)
    acceptance = {
        "families_planned_within_1p1x_oracle": sum(
            1 for f in fam_results.values() if f["planned_vs_oracle"] <= 1.1),
        "num_families": len(fam_results),
        "fixed_at_least_2x_worse_somewhere": any(
            f["fixed_vs_oracle"] >= 2.0 for f in fam_results.values()),
        "max_fixed_vs_oracle": max(
            f["fixed_vs_oracle"] for f in fam_results.values()),
        "total_probe_matvecs": total_probe_matvecs,
        "total_solve_matvecs_planned": total_solve_matvecs,
        "total_probe_cost_fraction": probe_cost_fraction,
    }
    rows.append((
        "spectral/acceptance", 0.0,
        f"within_1p1x={acceptance['families_planned_within_1p1x_oracle']}"
        f"/{acceptance['num_families']};"
        f"max_fixed_vs_oracle={acceptance['max_fixed_vs_oracle']:.2f};"
        f"probe_cost_fraction={probe_cost_fraction:.4f}",
    ))
    write_bench_json(
        "spectral", rows,
        extra={"families": fam_results, "acceptance": acceptance,
               "config": {"k": K, "budget": BUDGET, "tol": TOL, "lr": LR,
                          "chunk": CHUNK, "max_steps": MAX_STEPS,
                          "num_probes": NUM_PROBES, "num_steps": NUM_STEPS,
                          "fixed_degree": FIXED_DEGREE,
                          "fixed_strength": FIXED_STRENGTH}})
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
