"""Paper Figs. 1-3: 3-room MDP proto-value functions.

Longest eigenvector streak + subspace error vs steps, for mu-EG and Oja,
across the transform suite.  Reduced size (s=1) for CPU wall time; the
qualitative claim (series transform accelerates by ~an order of
magnitude) is asserted by tests/test_solvers.py as well.
"""
from __future__ import annotations

from benchmarks.common import convergence_run, paper_transform_suite, time_call
from repro.core import graphs, laplacian_dense, spectral_radius_upper_bound
from repro.core import operators


def run(k: int = 6, steps: int = 1500):
    g, _ = graphs.three_room_mdp(s=1, h=10)
    rho = float(spectral_radius_upper_bound(g))
    rows = []
    for name, tf in paper_transform_suite(rho, degree=151).items():
        for method in ("mu_eg", "oja"):
            lr = 2e-2 if name == "identity" else 0.4
            r = convergence_run(g, tf, method, lr, steps, k)
            op = operators.series_operator(
                tf, operators.dense_matvec(laplacian_dense(g)))
            import jax.numpy as jnp
            import jax
            v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, k))
            us = time_call(jax.jit(op), v, iters=3)
            rows.append((f"mdp/{name}/{method}", us,
                         f"streak@{r['steps_to_streak']}"
                         f";err1pct@{r['steps_to_1pct']}"
                         f";final_streak={r['final_streak']}/{k}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
