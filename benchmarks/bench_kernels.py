"""Pallas kernel benchmarks: per-kernel micro rows plus backend-vs-
segment END-TO-END solve timings, tracked in BENCH_kernels.json.

CPU caveat: pallas kernels execute via interpret=True on CPU (the kernel
body lowered through a grid loop) so absolute pallas numbers are NOT TPU
projections; the segment path is timed as the comparable baseline and
the derived column records the cross-backend max-abs delta (the perf
claims live in the roofline analysis, not here).  What this file tracks
across PRs is (a) that the pallas path stays numerically glued to
segment end-to-end, and (b) the segment hot-path trajectory; on TPU the
same harness times the real kernels.

The solve rows run the full operator -> solver pipeline on two graph
sizes: one inside the one-hot kernel's VMEM limit and one ABOVE the old
ONE_HOT_NODE_LIMIT (4096) ceiling, exercising the node-blocked layout.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call, write_bench_json
from repro.core import backend as backend_mod
from repro.core import graphs, operators, solvers
from repro.core import laplacian as lap
from repro.core.series import limit_neg_exp
from repro.kernels.edge_spmm import ops as es_ops, ref as es_ref
from repro.kernels.eg_update import ops as eg_ops, ref as eg_ref
from repro.kernels.laplacian_poly import ops as lp_ops, ref as lp_ref

# (tag, n, avg_deg_in, series degree, solver steps); n=9216 sits above
# backend.ONE_HOT_NODE_LIMIT (4096) => node-blocked path.
SOLVE_SIZES = (
    ("n2048", 2048, 4.0, 7, 4),
    ("n9216", 9216, 3.0, 5, 2),
)


def _micro_rows(key):
    rows = []
    n, k = 512, 8
    l_mat = jax.random.normal(key, (n, n)) / 32
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, k))

    ref_fn = jax.jit(lambda: lp_ref.poly_step(l_mat, u, 0.01))
    us = time_call(ref_fn, iters=5)
    kout = lp_ops.poly_step(l_mat, u, 0.01, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/poly_step_ref_n512", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))

    e = 4096
    src = jax.random.randint(jax.random.fold_in(key, 2), (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(key, 3), (e,), 0, n)
    w = jax.random.uniform(jax.random.fold_in(key, 4), (e,))
    ref_fn = jax.jit(lambda: es_ref.edge_spmm(src, dst, w, u))
    us = time_call(ref_fn, iters=5)
    kout = es_ops.edge_spmm(src, dst, w, u, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/edge_spmm_ref_e4096", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))

    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=128)
    nb_fn = lambda: es_ops.edge_spmm_blocked(nb, u, interpret=True)
    us = time_call(nb_fn, iters=5)
    err = float(jnp.max(jnp.abs(nb_fn() - ref_fn())))
    # interpret-mode pallas timings are informational (us_per_call=0
    # rows are exempt from run.py --check); the maxerr column stays the
    # gated signal
    interp = backend_mod.kernel_interpret()
    rows.append(("kernels/edge_spmm_nb_e4096",
                 0.0 if interp else round(us, 1),
                 f"kernel_maxerr={err:.2g},chunks={nb.num_chunks}"
                 + (f",interp_us={us:.0f}" if interp else "")))

    v = u / jnp.linalg.norm(u, axis=0, keepdims=True)
    av = jax.random.normal(jax.random.fold_in(key, 5), (n, k))
    ref_fn = jax.jit(lambda: eg_ref.mu_eg_update(v, av, 0.05))
    us = time_call(ref_fn, iters=5)
    kout = eg_ops.mu_eg_update(v, av, 0.05, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/eg_update_ref_n512", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))
    return rows


def _solve_rows():
    """End-to-end: tuned-series operator -> mu-EG solve, per backend.

    Two numbers per (size, backend): the WARM jitted operator
    application (the solve hot path — one full series of fused matvecs
    over the panel; this is the trajectory tracked across PRs) and one
    cold full-solve wall time (jit + `steps` solver steps; run_solver
    re-traces per call, so repeating it would time the compiler, not
    the solve).
    """
    rows = []
    extra = {}
    for tag, n, deg_in, degree, steps in SOLVE_SIZES:
        g, _ = graphs.sparse_sbm_graph(n, 4, avg_degree_in=deg_in,
                                       avg_degree_out=0.5, seed=0)
        rho = float(lap.spectral_radius_upper_bound(g))
        s = limit_neg_exp(degree, scale=8.0 / rho)
        cfg_base = solvers.SolverConfig(
            method="mu_eg", lr=0.3, steps=steps, eval_every=max(steps, 1),
            k=6, seed=0)
        v0 = jax.random.normal(jax.random.PRNGKey(1), (n, cfg_base.k))
        results = {}
        for b in ("segment", "pallas"):
            op_jit = jax.jit(operators.edge_series_operator(g, s, backend=b))
            op_us = time_call(op_jit, v0, iters=3)
            cfg = dataclasses.replace(cfg_base, backend=b)
            t0 = time.perf_counter()
            state, _ = solvers.run_solver(
                operators.edge_series_operator(g, s, backend=b), n, cfg)
            v_final = jax.block_until_ready(state.v)
            solve_cold_s = time.perf_counter() - t0
            results[b] = (op_us, solve_cold_s, v_final)
        delta = float(jnp.max(jnp.abs(results["segment"][2]
                                      - results["pallas"][2])))
        for b in ("segment", "pallas"):
            op_us, solve_cold_s, _ = results[b]
            interp = b == "pallas" and backend_mod.kernel_interpret()
            mode = "interpret" if interp else "native"
            # interpret-mode rows time the pallas grid loop, not the
            # kernel: report us_per_call=0 (informational, exempt from
            # run.py --check) and keep the measured number in derived;
            # xbackend_maxerr stays the gated signal either way
            rows.append((
                f"kernels/op_apply_{tag}_{b}",
                0.0 if interp else round(op_us, 1),
                f"degree={degree},mode={mode},"
                f"xbackend_maxerr={delta:.2g}"
                + (f",interp_us={op_us:.0f}" if interp else "")))
            rows.append((
                f"kernels/solve_cold_{tag}_{b}",
                0.0 if interp else round(solve_cold_s * 1e6, 1),
                f"steps={steps},incl_compile=1,mode={mode}"
                + (f",interp_us={solve_cold_s * 1e6:.0f}"
                   if interp else "")))
        extra[tag] = {
            "n": n,
            "num_edges": int(g.num_edges),
            "degree": degree,
            "solver_steps": steps,
            "node_blocked": n > backend_mod.ONE_HOT_NODE_LIMIT,
            "op_apply_us_segment": results["segment"][0],
            "op_apply_us_pallas": results["pallas"][0],
            "solve_cold_s_segment": results["segment"][1],
            "solve_cold_s_pallas": results["pallas"][1],
            "cross_backend_maxerr": delta,
        }
    return rows, extra


def _skew_rows():
    """Skew acceptance: on an alpha=2.5 power-law graph (hub blocks
    concentrate half-edges) the CSR chunk layout — per-block chunk
    counts, ONE pow2 snap of the total — must walk >= 2x fewer padded
    half-edge slots than the legacy uniform layout (every block pays
    the worst bucket's snapped chunk count), and the segment-form
    matvec over the SAME layout arrays gets faster in proportion.  The
    uniform layout no longer exists in the library, so its arrays are
    synthesized here as the baseline."""
    n, block_n, block_e, k = 4096, 256, 128, 8
    g = graphs.power_law_graph(n, avg_degree=8.0, alpha=2.5, seed=0)
    nb = es_ops.build_node_blocking(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.weight), n,
        block_n=block_n, block_e=block_e)
    u, o, w2, counts = es_ops._block_sorted_half_edges(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.weight),
        block_n, nb.num_blocks)
    uniform_slots = es_ops.uniform_padded_half_edges(counts, block_e)
    work_ratio = uniform_slots / nb.padded_half_edges
    # synthesized legacy arrays: block b's bucket starts at slot
    # b * C * BE, trailing slots stay inert zero-weight padding
    nbk, c_uni = nb.num_blocks, es_ops.uniform_chunks_for_counts(
        counts, block_e)
    ul = np.zeros((uniform_slots,), np.int32)
    ot = np.zeros((uniform_slots,), np.int32)
    wt = np.zeros((uniform_slots,), np.float32)
    offs = np.concatenate([[0], np.cumsum(counts)])
    blk_of = np.repeat(np.arange(nbk, dtype=np.int64), counts)
    slot = (blk_of * c_uni * block_e
            + (np.arange(u.shape[0]) - offs[blk_of]))
    ul[slot] = (u - blk_of * block_n).astype(np.int32)
    ot[slot] = o.astype(np.int32)
    wt[slot] = w2
    cb_uni = np.repeat(np.arange(nbk, dtype=np.int32), c_uni)

    deg = jnp.asarray(nb.deg)
    n_pad = int(deg.shape[0])
    v = jax.random.normal(jax.random.PRNGKey(9), (n_pad, k))

    def seg_mv(ul_a, ot_a, wt_a, blk_a):
        dest = blk_a * block_n + ul_a

        @jax.jit
        def mv(x):
            av = jnp.zeros((n_pad, k), jnp.float32).at[dest].add(
                wt_a[:, None] * x[ot_a])
            return deg[:, None] * x - av
        return mv

    mv_csr = seg_mv(nb.u_local, nb.other, nb.weight,
                    jnp.repeat(jnp.asarray(nb.chunk_block[:nb.num_chunks]),
                               block_e))
    mv_uni = seg_mv(jnp.asarray(ul), jnp.asarray(ot), jnp.asarray(wt),
                    jnp.repeat(jnp.asarray(cb_uni), block_e))
    err = float(jnp.max(jnp.abs(mv_csr(v) - mv_uni(v))))
    us_csr = time_call(mv_csr, v, iters=5)
    us_uni = time_call(mv_uni, v, iters=5)
    rows = [
        (f"kernels/skew_seg_mv_csr_n{n}", round(us_csr, 1),
         f"slots={nb.padded_half_edges},alpha=2.5,layout_maxerr={err:.2g}"),
        (f"kernels/skew_seg_mv_uniform_n{n}", round(us_uni, 1),
         f"slots={uniform_slots},alpha=2.5"),
    ]
    extra = {
        "n": n,
        "num_edges": int(g.num_edges),
        "block_n": block_n,
        "block_e": block_e,
        "padded_half_edges_csr": int(nb.padded_half_edges),
        "padded_half_edges_uniform": int(uniform_slots),
        "segment_matvec_us_csr": us_csr,
        "segment_matvec_us_uniform": us_uni,
    }
    return rows, extra, work_ratio


def run():
    rows = _micro_rows(jax.random.PRNGKey(0))
    solve_rows, extra = _solve_rows()
    rows += solve_rows
    skew_rows, skew, work_ratio = _skew_rows()
    rows += skew_rows
    write_bench_json("kernels", rows, extra={
        "solves": extra,
        "skew": skew,
        # gated (higher-is-better): layout math, not wall noise
        "skew_padded_work_speedup": work_ratio,
        "pallas_mode": ("interpret" if backend_mod.kernel_interpret()
                        else "native"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
