"""Pallas kernel micro-benchmarks.

CPU caveat: pallas kernels execute via interpret=True on CPU (a Python
interpreter of the kernel body) so absolute numbers are NOT TPU
projections; the jnp reference path is timed as the comparable baseline
and the derived column records the kernel/ref allclose delta (the perf
claims live in the roofline analysis, not here)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.kernels.edge_spmm import ops as es_ops, ref as es_ref
from repro.kernels.eg_update import ops as eg_ops, ref as eg_ref
from repro.kernels.laplacian_poly import ops as lp_ops, ref as lp_ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    n, k = 512, 8
    l_mat = jax.random.normal(key, (n, n)) / 32
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, k))

    ref_fn = jax.jit(lambda: lp_ref.poly_step(l_mat, u, 0.01))
    us = time_call(ref_fn, iters=5)
    kout = lp_ops.poly_step(l_mat, u, 0.01, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/poly_step_ref_n512", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))

    e = 4096
    src = jax.random.randint(jax.random.fold_in(key, 2), (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(key, 3), (e,), 0, n)
    w = jax.random.uniform(jax.random.fold_in(key, 4), (e,))
    ref_fn = jax.jit(lambda: es_ref.edge_spmm(src, dst, w, u))
    us = time_call(ref_fn, iters=5)
    kout = es_ops.edge_spmm(src, dst, w, u, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/edge_spmm_ref_e4096", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))

    v = u / jnp.linalg.norm(u, axis=0, keepdims=True)
    av = jax.random.normal(jax.random.fold_in(key, 5), (n, k))
    ref_fn = jax.jit(lambda: eg_ref.mu_eg_update(v, av, 0.05))
    us = time_call(ref_fn, iters=5)
    kout = eg_ops.mu_eg_update(v, av, 0.05, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/eg_update_ref_n512", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
