"""Pallas kernel benchmarks: per-kernel micro rows plus backend-vs-
segment END-TO-END solve timings, tracked in BENCH_kernels.json.

CPU caveat: pallas kernels execute via interpret=True on CPU (the kernel
body lowered through a grid loop) so absolute pallas numbers are NOT TPU
projections; the segment path is timed as the comparable baseline and
the derived column records the cross-backend max-abs delta (the perf
claims live in the roofline analysis, not here).  What this file tracks
across PRs is (a) that the pallas path stays numerically glued to
segment end-to-end, and (b) the segment hot-path trajectory; on TPU the
same harness times the real kernels.

The solve rows run the full operator -> solver pipeline on two graph
sizes: one inside the one-hot kernel's VMEM limit and one ABOVE the old
ONE_HOT_NODE_LIMIT (4096) ceiling, exercising the node-blocked layout.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call, write_bench_json
from repro.core import backend as backend_mod
from repro.core import graphs, operators, solvers
from repro.core import laplacian as lap
from repro.core.series import limit_neg_exp
from repro.kernels.edge_spmm import ops as es_ops, ref as es_ref
from repro.kernels.eg_update import ops as eg_ops, ref as eg_ref
from repro.kernels.laplacian_poly import ops as lp_ops, ref as lp_ref

# (tag, n, avg_deg_in, series degree, solver steps); n=9216 sits above
# backend.ONE_HOT_NODE_LIMIT (4096) => node-blocked path.
SOLVE_SIZES = (
    ("n2048", 2048, 4.0, 7, 4),
    ("n9216", 9216, 3.0, 5, 2),
)


def _micro_rows(key):
    rows = []
    n, k = 512, 8
    l_mat = jax.random.normal(key, (n, n)) / 32
    u = jax.random.normal(jax.random.fold_in(key, 1), (n, k))

    ref_fn = jax.jit(lambda: lp_ref.poly_step(l_mat, u, 0.01))
    us = time_call(ref_fn, iters=5)
    kout = lp_ops.poly_step(l_mat, u, 0.01, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/poly_step_ref_n512", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))

    e = 4096
    src = jax.random.randint(jax.random.fold_in(key, 2), (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(key, 3), (e,), 0, n)
    w = jax.random.uniform(jax.random.fold_in(key, 4), (e,))
    ref_fn = jax.jit(lambda: es_ref.edge_spmm(src, dst, w, u))
    us = time_call(ref_fn, iters=5)
    kout = es_ops.edge_spmm(src, dst, w, u, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/edge_spmm_ref_e4096", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))

    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=128)
    nb_fn = lambda: es_ops.edge_spmm_blocked(nb, u, interpret=True)
    us = time_call(nb_fn, iters=5)
    err = float(jnp.max(jnp.abs(nb_fn() - ref_fn())))
    rows.append(("kernels/edge_spmm_nb_e4096", round(us, 1),
                 f"kernel_maxerr={err:.2g},chunks={nb.chunks_per_block}"))

    v = u / jnp.linalg.norm(u, axis=0, keepdims=True)
    av = jax.random.normal(jax.random.fold_in(key, 5), (n, k))
    ref_fn = jax.jit(lambda: eg_ref.mu_eg_update(v, av, 0.05))
    us = time_call(ref_fn, iters=5)
    kout = eg_ops.mu_eg_update(v, av, 0.05, interpret=True)
    err = float(jnp.max(jnp.abs(kout - ref_fn())))
    rows.append(("kernels/eg_update_ref_n512", round(us, 1),
                 f"kernel_maxerr={err:.2g}"))
    return rows


def _solve_rows():
    """End-to-end: tuned-series operator -> mu-EG solve, per backend.

    Two numbers per (size, backend): the WARM jitted operator
    application (the solve hot path — one full series of fused matvecs
    over the panel; this is the trajectory tracked across PRs) and one
    cold full-solve wall time (jit + `steps` solver steps; run_solver
    re-traces per call, so repeating it would time the compiler, not
    the solve).
    """
    rows = []
    extra = {}
    for tag, n, deg_in, degree, steps in SOLVE_SIZES:
        g, _ = graphs.sparse_sbm_graph(n, 4, avg_degree_in=deg_in,
                                       avg_degree_out=0.5, seed=0)
        rho = float(lap.spectral_radius_upper_bound(g))
        s = limit_neg_exp(degree, scale=8.0 / rho)
        cfg_base = solvers.SolverConfig(
            method="mu_eg", lr=0.3, steps=steps, eval_every=max(steps, 1),
            k=6, seed=0)
        v0 = jax.random.normal(jax.random.PRNGKey(1), (n, cfg_base.k))
        results = {}
        for b in ("segment", "pallas"):
            op_jit = jax.jit(operators.edge_series_operator(g, s, backend=b))
            op_us = time_call(op_jit, v0, iters=3)
            cfg = dataclasses.replace(cfg_base, backend=b)
            t0 = time.perf_counter()
            state, _ = solvers.run_solver(
                operators.edge_series_operator(g, s, backend=b), n, cfg)
            v_final = jax.block_until_ready(state.v)
            solve_cold_s = time.perf_counter() - t0
            results[b] = (op_us, solve_cold_s, v_final)
        delta = float(jnp.max(jnp.abs(results["segment"][2]
                                      - results["pallas"][2])))
        for b in ("segment", "pallas"):
            op_us, solve_cold_s, _ = results[b]
            mode = ("interpret" if b == "pallas"
                    and backend_mod.kernel_interpret() else "native")
            rows.append((
                f"kernels/op_apply_{tag}_{b}", round(op_us, 1),
                f"degree={degree},mode={mode},"
                f"xbackend_maxerr={delta:.2g}"))
            rows.append((
                f"kernels/solve_cold_{tag}_{b}",
                round(solve_cold_s * 1e6, 1),
                f"steps={steps},incl_compile=1,mode={mode}"))
        extra[tag] = {
            "n": n,
            "num_edges": int(g.num_edges),
            "degree": degree,
            "solver_steps": steps,
            "node_blocked": n > backend_mod.ONE_HOT_NODE_LIMIT,
            "op_apply_us_segment": results["segment"][0],
            "op_apply_us_pallas": results["pallas"][0],
            "solve_cold_s_segment": results["segment"][1],
            "solve_cold_s_pallas": results["pallas"][1],
            "cross_backend_maxerr": delta,
        }
    return rows, extra


def run():
    rows = _micro_rows(jax.random.PRNGKey(0))
    solve_rows, extra = _solve_rows()
    rows += solve_rows
    write_bench_json("kernels", rows, extra={
        "solves": extra,
        "pallas_mode": ("interpret" if backend_mod.kernel_interpret()
                        else "native"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
