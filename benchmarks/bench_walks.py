"""Paper Sec. 4.3: stochastic walk estimator — throughput (walks/s) and
relative error of L^2 estimates, rejection (paper) vs importance
weighting (beyond-paper), plus acceptance rate of the Eq. 14 coin."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import build_edge_incidence, laplacian_dense
from repro.core import graphs, walks


def run():
    g, _ = graphs.clique_graph(200, 4, seed=0)
    inc = build_edge_incidence(g)
    L = np.asarray(laplacian_dense(g))
    want = L @ L
    rows = []
    w = 20000
    sample = jax.jit(lambda k: walks.sample_walks(k, inc, w, 3))
    us = time_call(sample, jax.random.PRNGKey(0), iters=3)
    rows.append(("walks/sample_20k_len3", round(us, 1),
                 f"walks_per_s={w / (us / 1e6):.3g}"))
    wb = sample(jax.random.PRNGKey(1))
    for mode in ("importance", "rejection"):
        est = walks.estimate_power_dense(
            wb, g, inc, 2, g.num_nodes, mode=mode,
            key=jax.random.PRNGKey(2) if mode == "rejection" else None)
        rel = float(np.linalg.norm(np.asarray(est) - want)
                    / np.linalg.norm(want))
        fn = jax.jit(lambda v, m=mode: walks.estimate_power_matvec(
            wb, g, inc, 2, v, mode=m,
            key=jax.random.PRNGKey(2) if m == "rejection" else None))
        v = jnp.ones((g.num_nodes, 8))
        us = time_call(fn, v, iters=3)
        rows.append((f"walks/estimate_L2_{mode}", round(us, 1),
                     f"rel_err={rel:.3g}"))
    # acceptance rate of the paper's rejection coin
    log_pmin = -2 * np.log(inc.deg_star_inc) - np.log(g.num_edges)
    p_acc = np.exp(np.minimum(log_pmin - np.asarray(wb.logp[:, 1]), 0.0))
    rows.append(("walks/rejection_acceptance", 0.0,
                 f"mean_acc={float(p_acc.mean()):.3g}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
