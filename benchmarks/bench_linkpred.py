"""Paper Fig. 5 / App. A.1: clustering graphs completed by
common-neighbors link prediction (weighted Laplacian)."""
from __future__ import annotations

from benchmarks.common import convergence_run, paper_transform_suite
from repro.core import graphs, linkpred, spectral_radius_upper_bound


def run(steps: int = 1000):
    rows = []
    g, _ = graphs.clique_graph(300, 3, seed=1)
    gw = linkpred.complete_graph(g, drop_prob=0.2, seed=2)
    rho = float(spectral_radius_upper_bound(gw))
    for name, tf in paper_transform_suite(rho).items():
        lr = 2e-2 if name == "identity" else 0.4
        r = convergence_run(gw, tf, "mu_eg", lr, steps, 3)
        rows.append((f"linkpred/{name}",
                     round(r["wall_s"] * 1e6 / steps, 1),
                     f"streak@{r['steps_to_streak']}"
                     f";final_streak={r['final_streak']}/3"
                     f";err={r['final_err']:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
