"""Beyond-paper comparison vs related work (paper App. B): SPED vs Bethe
Hessian (Saade et al. 2014) vs shift-and-invert (Garber et al. 2016) on
SBM community detection.  The paper cites both but compares against
neither; we do.

Cost accounting: shift-and-invert pays `cg_iters` Laplacian matvecs per
operator application (a linear solve), SPED pays `degree` matvecs of a
FIXED polynomial — same O() primitive, but SPED's is embarrassingly
parallel and unbiased under minibatching (the paper's §4.3 point).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (SolverConfig, laplacian_dense, limit_neg_exp,
                        run_solver, spectral_radius_upper_bound)
from repro.core import baselines, graphs, metrics, operators
from repro.core.kmeans import cluster_agreement, kmeans


def _cluster_from_vecs(vecs, k, truth):
    emb = vecs[:, 1: k + 1] if vecs.shape[1] > k else vecs[:, :k]
    emb = emb / jnp.maximum(jnp.linalg.norm(emb, axis=1, keepdims=True),
                            1e-12)
    labels = kmeans(jax.random.PRNGKey(1), emb, k).labels
    return float(cluster_agreement(labels, jnp.asarray(truth), k))


def run():
    g, truth = graphs.sbm_graph(240, 3, p_in=0.2, p_out=0.01, seed=0)
    L = laplacian_dense(g)
    k = 3
    rho = float(spectral_radius_upper_bound(g))
    rows = []

    # SPED (limit series + mu-EG)
    s = limit_neg_exp(151, scale=8.0 / rho)
    op = operators.series_operator(s, operators.dense_matvec(L))
    cfg = SolverConfig(method="mu_eg", lr=0.4, steps=500, eval_every=100,
                       k=k + 1)
    t0 = time.perf_counter()
    state, tr = run_solver(op, g.num_nodes, cfg)
    dt = time.perf_counter() - t0
    acc = _cluster_from_vecs(state.v, k, truth)
    rows.append(("baselines/sped_limit151", round(dt * 1e6 / cfg.steps, 1),
                 f"acc={acc:.3f};matvecs_per_step={s.degree}"))

    # shift-and-invert (CG inner solves)
    op_si = baselines.shift_invert_operator(
        operators.dense_matvec(L), shift=0.05, cg_iters=50)
    cfg_si = SolverConfig(method="oja", lr=0.5, steps=300, eval_every=100,
                          k=k + 1)
    t0 = time.perf_counter()
    state_si, _ = run_solver(op_si, g.num_nodes, cfg_si)
    dt = time.perf_counter() - t0
    acc = _cluster_from_vecs(state_si.v, k, truth)
    rows.append(("baselines/shift_invert_cg50",
                 round(dt * 1e6 / cfg_si.steps, 1),
                 f"acc={acc:.3f};matvecs_per_step=50"))

    # Bethe Hessian (direct eigendecomposition; not stochastic)
    t0 = time.perf_counter()
    labels, info = baselines.bethe_hessian_cluster(g, k)
    dt = time.perf_counter() - t0
    acc = float(cluster_agreement(labels, jnp.asarray(truth), k))
    rows.append(("baselines/bethe_hessian_eigh", round(dt * 1e6, 1),
                 f"acc={acc:.3f};r={info['r']:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
