"""Shared benchmark utilities: timing, the paper's convergence protocol,
and the machine-readable BENCH_<name>.json writer that tracks the perf
trajectory across PRs."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SolverConfig, identity_series, laplacian_dense,
                        limit_neg_exp, run_solver, steps_to_streak,
                        steps_to_tolerance, taylor_log, taylor_neg_exp,
                        with_lambda_star)
from repro.core import metrics, operators
from repro.core.series import cheb_log


def write_bench_json(name: str, rows, extra: dict | None = None) -> str:
    """Write BENCH_<name>.json at the repo root; returns the path.

    ``rows`` are the harness's (name, us_per_call, derived) CSV triples;
    ``extra`` carries benchmark-specific structured results (e.g. the
    spectral planner's per-family iteration counts).  One schema for
    every bench module so the perf trajectory is diffable across PRs.
    """
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo_root, f"BENCH_{name}.json")
    payload = {
        "schema_version": 1,
        "bench": name,
        "rows": [
            {"name": n, "us_per_call": float(us), "derived": str(derived)}
            for n, us, derived in rows
        ],
    }
    if extra:
        payload.update(extra)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def bench_regressions(old: dict, new: dict,
                      threshold: float = 0.25) -> list[str]:
    """Key-metric diff between two BENCH payloads (``run.py --check``).

    Flags a regression when a row shared by both payloads got more than
    ``threshold`` slower (``us_per_call``), or when a top-level numeric
    higher-is-better metric (key contains ``speedup``) dropped by more
    than the same factor.  Rows/keys present on only one side are new
    or retired metrics, not regressions.  Returns human-readable
    messages (empty = no regression).
    """
    msgs = []
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    for r in new.get("rows", []):
        o = old_rows.get(r["name"])
        if o is None or o.get("us_per_call", 0) <= 0:
            continue
        if r["us_per_call"] > o["us_per_call"] * (1.0 + threshold):
            msgs.append(
                f"{r['name']}: us_per_call {o['us_per_call']:.0f} -> "
                f"{r['us_per_call']:.0f} (> +{threshold:.0%})")
    for key, val in new.items():
        if not isinstance(val, (int, float)) or isinstance(val, bool):
            continue
        o = old.get(key)
        if not isinstance(o, (int, float)) or isinstance(o, bool) or o <= 0:
            continue
        if "speedup" in key and val < o / (1.0 + threshold):
            msgs.append(f"{key}: {o:.3g} -> {val:.3g} (< -{threshold:.0%})")
    return msgs


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds (jits + blocks)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def paper_transform_suite(rho_ub: float, degree: int = 251):
    """The transformations compared in the paper's figures:
    identity | exact -e^{-L} (via scalar map) | limit series | taylor-log
    plus our beyond-paper chebyshev-log."""
    return {
        "identity": with_lambda_star(identity_series(), rho_ub * 1.01),
        "limit_neg_exp": limit_neg_exp(degree),
        "limit_neg_exp_scaled": limit_neg_exp(
            degree, scale=8.0 / rho_ub),
        "cheb_log(beyond)": cheb_log(64, rho=rho_ub),
    }


def convergence_run(g, transform, method: str, lr: float, steps: int, k: int,
                    v_star=None, eval_every: int = 25):
    """Paper protocol: run solver, report steps-to-full-streak and
    steps-to-1% subspace error."""
    L = laplacian_dense(g)
    if v_star is None:
        _, v_star = metrics.ground_truth_bottom_k(L, k)
    op = operators.series_operator(transform, operators.dense_matvec(L))
    cfg = SolverConfig(method=method, lr=lr, steps=steps,
                       eval_every=eval_every, k=k, seed=0)
    t0 = time.perf_counter()
    _, trace = run_solver(op, g.num_nodes, cfg, v_star=v_star)
    wall = time.perf_counter() - t0
    return {
        "steps_to_streak": steps_to_streak(trace, k),
        "steps_to_1pct": steps_to_tolerance(trace, 0.01),
        "final_err": float(trace.subspace_error[-1]),
        "final_streak": int(trace.streak[-1]),
        "wall_s": wall,
    }
