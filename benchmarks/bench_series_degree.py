"""Paper Fig. 6 / App. A.2: accuracy of the series approximation vs
degree (11/51/151/251).  Reproduces the claim that insufficient degree
fails to accelerate, and that the limit approximation dominates the
Taylor forms; adds the beyond-paper scaled/chebyshev variants that fix
the low-degree failures."""
from __future__ import annotations

from benchmarks.common import convergence_run
from repro.core import (graphs, limit_neg_exp, spectral_radius_upper_bound,
                        taylor_log, taylor_neg_exp)
from repro.core.series import cheb_neg_exp


def run(steps: int = 900):
    g, _ = graphs.clique_graph(300, 3, seed=0)
    rho = float(spectral_radius_upper_bound(g))
    k = 3
    rows = []
    series = []
    for d in (11, 51, 151, 251):
        series.append((f"limit_neg_exp_d{d}", limit_neg_exp(d)))
        series.append((f"taylor_neg_exp_d{d}", taylor_neg_exp(d)))
    series.append(("limit_d51_scaled(beyond)",
                   limit_neg_exp(51, scale=8.0 / rho)))
    series.append(("cheb_d16(beyond)", cheb_neg_exp(16, rho=rho, tau=8.0 / rho)))
    for name, tf in series:
        r = convergence_run(g, tf, "mu_eg", 0.4, steps, k)
        rows.append((f"series_degree/{name}",
                     round(r["wall_s"] * 1e6 / steps, 1),
                     f"streak@{r['steps_to_streak']}"
                     f";final_streak={r['final_streak']}/{k}"
                     f";err={r['final_err']:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
