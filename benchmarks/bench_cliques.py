"""Paper Fig. 4: clique graphs (n nodes, k cliques, 0-25 short circuits).

Includes the paper's failure regime: when rho(L) ~ clique size exceeds
~2*degree, the raw limit series folds and fails, while the beyond-paper
auto-scaled series keeps working (Sec. 5.4 hypothesis, which our
Fig. 6-style degree sweep in bench_series_degree.py also probes).
"""
from __future__ import annotations

from benchmarks.common import convergence_run, paper_transform_suite
from repro.core import graphs, spectral_radius_upper_bound


def run(steps: int = 1200):
    rows = []
    for n, k in ((300, 3), (400, 4)):
        g, _ = graphs.clique_graph(n, k, seed=0)
        rho = float(spectral_radius_upper_bound(g))
        for name, tf in paper_transform_suite(rho).items():
            lr = 2e-2 if name == "identity" else 0.4
            r = convergence_run(g, tf, "mu_eg", lr, steps, k)
            rows.append((f"cliques_n{n}_k{k}/{name}",
                         round(r["wall_s"] * 1e6 / steps, 1),
                         f"streak@{r['steps_to_streak']}"
                         f";final_streak={r['final_streak']}/{k}"
                         f";err={r['final_err']:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
