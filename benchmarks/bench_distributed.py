"""Weak-scaling benchmarks for sharded serving (BENCH_distributed.json).

Rows track the mesh-parallel path PR 4 built: the sharded Laplacian
matvec and the sharded streaming tick at 1/2/4/8 virtual devices on a
fixed n=9216 problem (weak scaling of the collective footprint: the
per-shard edge slice shrinks as devices grow, the psum'd (n, k) panel
does not), plus the acceptance row — a sharded n=9216 solve past
``ONE_HOT_NODE_LIMIT`` running PER-SHARD NODE BLOCKINGS on the pallas
backend, cross-checked against the sharded segment solve.

Device counts must be fixed before jax initializes, so ``run()`` spawns
ONE SUBPROCESS PER DEVICE COUNT with
``XLA_FLAGS=--xla_force_host_platform_device_count=D`` re-running this
module in child mode; children print JSON rows on stdout.  CPU caveat
(same as bench_kernels): the virtual devices share one host and pallas
runs in interpret mode, so these rows track correctness-adjacent
latency trends and collective overhead, NOT TPU speedups — on a real
mesh the same harness times the real thing.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

N = 9216  # past backend.ONE_HOT_NODE_LIMIT => node-blocked layouts
DEGREE = 5
SOLVE_STEPS = 2
DEVICE_COUNTS = (1, 2, 4, 8)


def _graph():
    from repro.core import graphs

    g, _ = graphs.sparse_sbm_graph(N, 4, avg_degree_in=3.0,
                                   avg_degree_out=0.5, seed=0)
    return g


def _child(num_devices: int) -> list:
    """Runs inside the XLA_FLAGS subprocess; returns (name, us, derived)
    rows for this device count."""
    import time

    import jax
    import jax.numpy as jnp

    from benchmarks.common import time_call
    from repro.compat import default_edge_mesh
    from repro.core import backend as backend_mod
    from repro.core import distributed, solvers
    from repro.core import laplacian as lap
    from repro.core.series import limit_neg_exp
    from repro.stream.service import ServiceConfig, StreamingService

    assert jax.device_count() == num_devices, (
        jax.device_count(), num_devices)
    d = num_devices
    mesh = default_edge_mesh()
    g = _graph()
    rows = []

    # --- sharded segment matvec (the tick/solve hot path's inner op) --
    gp = distributed.pad_edges_for_mesh(g, d)
    mv = distributed.sharded_laplacian_matvec(mesh, backend="segment")
    v = jax.random.normal(jax.random.PRNGKey(0), (N, 6))
    us = time_call(lambda: mv(gp.src, gp.dst, gp.weight, v), iters=5)
    rows.append((f"distributed/matvec_n{N}_d{d}", round(us, 1),
                 f"edges_per_shard={gp.num_edges // d}"))

    # --- warm sharded streaming tick (ServiceConfig(mesh=...)) --------
    svc = StreamingService(ServiceConfig(
        backend="segment", mesh=mesh, k=6, num_clusters=4,
        degree=7, steps_per_tick=5, seed=0))
    svc.add_graph("wk", g)
    svc.tick()  # compile + first tick
    t0 = time.perf_counter()
    svc.tick()
    warm_us = (time.perf_counter() - t0) * 1e6
    sess = svc.session_info("wk")
    rows.append((f"distributed/tick_warm_n{N}_d{d}", round(warm_us, 1),
                 f"degree=7,steps=5,edge_cap={sess['edge_capacity']},"
                 f"rho={sess['rho']:.3g}"))

    # --- panel-sharded model tick (weak scaling of the fused path) ----
    # the derived column carries the trace-time collective budget: the
    # mu-EG model tick must issue EXACTLY ONE fused (rows+gram) psum
    # per solver step at EVERY device count
    import numpy as np

    from repro.core import program

    mmesh = jax.sharding.Mesh(
        np.array(jax.devices()).reshape(1, d), ("data", "model"))
    mb = backend_mod.build_model_sharded_blocking(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.weight),
        N, d, block_n=512)
    sched = program.StepSchedule(method="mu_eg", degree=7, steps=5,
                                 backend="segment")
    tick = program.build_tick_model_sharded(
        sched, mmesh, ("model",), mb.block_n, mb.num_chunks, mb.block_e)
    v0 = jax.random.normal(jax.random.PRNGKey(2), (1, N, 6))
    args = (mb.u_local[None], mb.other[None], mb.weight[None],
            mb.chunk_block[None], mb.deg[None], v0,
            jnp.asarray([0.01], jnp.float32),
            jnp.asarray([0.3], jnp.float32), jnp.asarray(1, jnp.int32))
    with program.count_psums() as st:
        jax.eval_shape(tick, *args)
    us = time_call(lambda: tick(*args), iters=3)
    rows.append((f"distributed/model_tick_warm_n{N}_d{d}", round(us, 1),
                 f"degree=7,steps=5,shards={d},"
                 f"fused_psums={st.fused},plain_psums={st.plain},"
                 f"padded_half_edges={mb.padded_half_edges}"))

    # --- acceptance row: sharded node-blocked pallas solve ------------
    # (only at the top device count — interpret-mode pallas is slow)
    if d == max(DEVICE_COUNTS):
        rho = float(lap.spectral_radius_upper_bound(g))
        s = limit_neg_exp(DEGREE, scale=8.0 / rho)
        cfg = solvers.SolverConfig(
            method="mu_eg", lr=0.3, steps=SOLVE_STEPS,
            eval_every=SOLVE_STEPS, k=6, seed=0)
        panels = {}
        for b in ("segment", "pallas"):
            op = distributed.distributed_series_operator(
                mesh, g, s, backend=b)
            t0 = time.perf_counter()
            state, _ = solvers.run_solver(op, N, cfg)
            panels[b] = jax.block_until_ready(state.v)
            wall = time.perf_counter() - t0
            mode = ("interpret" if b == "pallas"
                    and backend_mod.kernel_interpret() else "native")
            rows.append((
                f"distributed/solve_nb_n{N}_d{d}_{b}",
                round(wall * 1e6, 1),
                f"steps={SOLVE_STEPS},degree={DEGREE},mode={mode},"
                f"per_shard_blocking={b == 'pallas'},"
                f"one_hot_limit={backend_mod.ONE_HOT_NODE_LIMIT}"))
        err = float(jnp.max(jnp.abs(panels["segment"] - panels["pallas"])))
        rows[-1] = (rows[-1][0], rows[-1][1],
                    rows[-1][2] + f",xbackend_maxerr={err:.2g}")
    return rows


def run():
    """Parent: spawn one child per device count, collect rows, write
    BENCH_distributed.json."""
    from benchmarks.common import write_bench_json

    here = os.path.abspath(__file__)
    root = os.path.dirname(os.path.dirname(here))
    rows = []
    weak = {}
    for d in DEVICE_COUNTS:
        env = dict(os.environ)
        # forced flag LAST: XLA parses duplicate flags last-wins, so an
        # inherited device-count flag (e.g. the distributed lane's 8)
        # must not override this child's count
        env["XLA_FLAGS"] = (
            (env["XLA_FLAGS"] + " " if env.get("XLA_FLAGS") else "")
            + f"--xla_force_host_platform_device_count={d}")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        proc = subprocess.run(
            [sys.executable, here, "--child", str(d)],
            capture_output=True, text=True, env=env, cwd=root)
        if proc.returncode != 0:
            raise RuntimeError(
                f"bench_distributed child d={d} failed:\n{proc.stderr[-2000:]}")
        child_rows = json.loads(proc.stdout.strip().splitlines()[-1])
        rows.extend(tuple(r) for r in child_rows)
        for name, us, derived in child_rows:
            if name.startswith(f"distributed/tick_warm_n{N}_d"):
                weak[f"tick_warm_us_d{d}"] = us
            if name.startswith(f"distributed/matvec_n{N}_d"):
                weak[f"matvec_us_d{d}"] = us
            if name.startswith(f"distributed/model_tick_warm_n{N}_d"):
                weak[f"model_tick_warm_us_d{d}"] = us
                assert "fused_psums=1," in derived, derived
    write_bench_json("distributed", rows, extra={
        "weak_scaling": {
            "n": N,
            "device_counts": list(DEVICE_COUNTS),
            **weak,
        },
    })
    return rows


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--child":
        print(json.dumps(_child(int(sys.argv[2]))))
    else:
        for r in run():
            print(",".join(str(x) for x in r))
