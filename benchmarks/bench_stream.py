"""Streaming service benchmarks: graph-store update throughput,
iterations-to-reconverge (warm + dilation vs cold) on a >=10k-node SBM,
and the residual-decay tick scheduler vs round-robin on a mixed fleet.

The headline claims:
  * warm + dilation: after a 1% edge perturbation, warm-starting the
    previous eigenvector panel against the dilated operator reconverges
    in >= 3x fewer solver iterations than a cold solve (in practice far
    more);
  * scheduled ticks: on a fleet mixing fast- and slow-converging SBM
    tenants, forecasting each group's remaining steps from measured
    residual decay (ServiceConfig(tick_schedule="residual_decay"))
    reaches fleet convergence in a fraction of round-robin's compiled
    tick invocations — skipping the no-payoff intermediate residual
    evaluations and host round-trips — at equal per-tenant quality.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call, write_bench_json
from repro.core import graphs, make_edge_list, operators
from repro.core.kmeans import cluster_agreement
from repro.core.laplacian import spectral_radius_upper_bound
from repro.core.series import limit_neg_exp
from repro.stream import graph_store as gs
from repro.stream import warm
from repro.stream.service import ServiceConfig, StreamingService

N_NODES = 10_000
N_BLOCKS = 10
K = 8
DEGREE = 15
STRENGTH = 8.0
BATCH = 256

# mixed-fleet scheduler comparison
FLEET_N = 200
FLEET_FAST = 4  # well-separated tenants (few ticks to tolerance)
FLEET_SLOW = 4  # weak-structure tenants (many ticks to tolerance)
FLEET_CFG = ServiceConfig(
    k=6, num_clusters=4, degree=15, steps_per_tick=5, lr=0.3,
    tol=2e-3, dilation_strength=8.0, max_tick_multiplier=16, seed=0)


def _dilated_op(g):
    rho = float(spectral_radius_upper_bound(g))
    s = limit_neg_exp(DEGREE, scale=STRENGTH / rho)
    return operators.series_operator(s, operators.edge_matvec(g))


def _perturb_one_percent(g, seed=1):
    """Delete E/200 random edges and insert E/200 random new ones —
    1% of the edge set churned."""
    rng = np.random.default_rng(seed)
    e = g.num_edges
    m = max(e // 200, 1)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    keep = np.ones(e, bool)
    keep[rng.choice(e, size=m, replace=False)] = False
    add = np.sort(
        rng.integers(0, g.num_nodes, size=(m, 2)).astype(np.int32), axis=1)
    add = add[add[:, 0] != add[:, 1]]
    edges = np.concatenate(
        [np.stack([src[keep], dst[keep]], 1), add], axis=0)
    edges = np.unique(edges, axis=0)
    return make_edge_list(edges, g.num_nodes), 2 * m


def _fleet_graphs():
    """FLEET_FAST well-separated + FLEET_SLOW weak-structure tenants —
    the mixed convergence-rate fleet the scheduler is built for."""
    out = []
    for i in range(FLEET_FAST):
        g, lab = graphs.sbm_graph(FLEET_N, 4, p_in=0.35, p_out=0.01,
                                  seed=i)
        out.append((f"fast{i}", g, lab))
    for i in range(FLEET_SLOW):
        g, lab = graphs.sbm_graph(FLEET_N, 4, p_in=0.12, p_out=0.04,
                                  seed=100 + i)
        out.append((f"slow{i}", g, lab))
    return out


def _run_fleet(schedule: str, fleet, max_ticks: int = 600):
    svc = StreamingService(
        dataclasses.replace(FLEET_CFG, tick_schedule=schedule))
    for sid, g, _ in fleet:
        svc.add_graph(sid, g, edge_capacity=8192)
    t0 = time.perf_counter()
    svc.run_until_converged(max_ticks=max_ticks)
    wall = time.perf_counter() - t0
    agree = float(np.mean([
        cluster_agreement(jnp.asarray(svc.labels(sid)), jnp.asarray(lab),
                          FLEET_CFG.num_clusters)
        for sid, _, lab in fleet]))
    residuals = {sid: svc.session_info(sid)["residual"]
                 for sid, _, _ in fleet}
    return svc, wall, agree, residuals


def run():
    rows = []

    # -- residual-decay tick scheduler vs round-robin --------------------
    fleet = _fleet_graphs()
    results = {}
    for schedule in ("round_robin", "residual_decay"):
        svc, wall, agree, residuals = _run_fleet(schedule, fleet)
        results[schedule] = dict(
            wall_s=wall, agreement=agree,
            tick_invocations=svc.tick_invocations,
            device_work_steps=svc.device_work,
            converged=svc.all_converged,
            max_residual=max(residuals.values()))
        rows.append((
            f"stream/fleet{FLEET_FAST + FLEET_SLOW}_{schedule}",
            wall * 1e6,
            f"invocations={svc.tick_invocations};"
            f"device_steps={svc.device_work};"
            f"agreement={agree:.3f};converged={svc.all_converged}"))
        assert svc.all_converged
        assert max(residuals.values()) <= FLEET_CFG.tol
    tick_speedup = (results["round_robin"]["tick_invocations"]
                    / max(results["residual_decay"]["tick_invocations"], 1))
    wall_speedup = (results["round_robin"]["wall_s"]
                    / max(results["residual_decay"]["wall_s"], 1e-9))
    g, _ = graphs.sparse_sbm_graph(
        N_NODES, N_BLOCKS, avg_degree_in=10.0, avg_degree_out=1.0, seed=0)
    e = g.num_edges

    # -- graph store: batched update throughput --------------------------
    store = gs.from_edge_list(g)
    rng = np.random.default_rng(0)
    sel = rng.choice(e, size=BATCH, replace=False)
    pairs = np.stack([np.asarray(g.src)[sel], np.asarray(g.dst)[sel]], 1)
    batch = gs.make_edge_batch(pairs, rng.random(BATCH).astype(np.float32))
    us = time_call(
        lambda s, b: gs.apply_edge_batch(s, b)[0].weight, store, batch)
    rows.append((
        f"stream/apply_edge_batch_b{BATCH}_cap{store.capacity}", us,
        f"updates_per_s={BATCH / us * 1e6:.0f}"))

    # -- cold solve to tolerance -----------------------------------------
    cfg = warm.WarmConfig(tol=5e-3, chunk=10, max_steps=5000, lr=0.3)
    op = _dilated_op(g)
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    state, cold = warm.reconverge(key, op, g.num_nodes, K, cfg, v_prev=None)
    cold_wall = time.perf_counter() - t0
    rows.append((
        f"stream/cold_solve_n{N_NODES}_e{e}", cold_wall * 1e6,
        f"iters={cold['iterations']};residual={cold['residual']:.1e}"))

    # -- warm + dilation reconverge after 1% churn -----------------------
    g2, churned = _perturb_one_percent(g)
    op2 = _dilated_op(g2)
    t0 = time.perf_counter()
    _, winfo = warm.reconverge(key, op2, g.num_nodes, K, cfg,
                               v_prev=state.v)
    warm_wall = time.perf_counter() - t0
    speedup = cold["iterations"] / max(winfo["iterations"], cfg.chunk)
    rows.append((
        f"stream/warm_reconverge_churn{churned}", warm_wall * 1e6,
        f"iters={winfo['iterations']};warm={winfo['warm']};"
        f"iter_speedup={speedup:.1f}x"))
    assert winfo["residual"] <= cfg.tol
    write_bench_json(
        "stream", rows,
        extra={"config": {"n_nodes": N_NODES, "n_blocks": N_BLOCKS, "k": K,
                          "degree": DEGREE, "strength": STRENGTH,
                          "batch": BATCH},
               "iter_speedup_warm_vs_cold": speedup,
               "fleet": {
                   "n": FLEET_N, "fast": FLEET_FAST, "slow": FLEET_SLOW,
                   "round_robin": results["round_robin"],
                   "residual_decay": results["residual_decay"],
               },
               "tick_speedup_scheduled_vs_round_robin": tick_speedup,
               "wall_speedup_scheduled_vs_round_robin": wall_speedup})
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
