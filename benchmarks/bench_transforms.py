"""Paper Table 2: the transformation functions — analytic eigengap
dilation factor on a synthetic well-clustered spectrum plus operator
apply cost (us) at n=512, k=8."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import (identity_series, limit_neg_exp, taylor_log,
                        taylor_neg_exp, with_lambda_star)
from repro.core.series import cheb_log, cheb_neg_exp
from repro.core.transforms import eigengap_ratio


def run():
    # synthetic spectrum: 4 bottom eigenvalues << bulk (well-clustered)
    lam = jnp.concatenate([
        jnp.asarray([0.0, 0.05, 0.08, 0.12]),
        jnp.linspace(20.0, 60.0, 60),
    ])
    rho = float(lam[-1])
    k = 4
    suite = {
        "identity": with_lambda_star(identity_series(), rho * 1.01),
        "taylor_log_d51": taylor_log(51, eps=0.05),
        "taylor_neg_exp_d51": taylor_neg_exp(51),
        "limit_neg_exp_d251": limit_neg_exp(251),
        "limit_neg_exp_d251_s8": limit_neg_exp(251, scale=8.0 / rho),
        "cheb_log_d64": cheb_log(64, rho=rho),
        "cheb_neg_exp_d32": cheb_neg_exp(32, rho=rho, tau=8.0 / rho),
    }
    def conv_ratio(f_vals):
        # convergence-relevant ratio for recovering the BOTTOM-k of L
        # after transform f (monotone: order preserved): spectral range
        # over the min eigengap among the bottom k+1 transformed values
        f_vals = jnp.sort(f_vals.astype(jnp.float64)
                          if False else f_vals)
        gaps = jnp.diff(f_vals[: k + 1])
        rng = f_vals[-1] - f_vals[0]
        return float(rng / jnp.maximum(jnp.min(gaps), 1e-30))

    base = conv_ratio(lam)
    n = 512
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n)) / np.sqrt(n)
    l_mat = a @ a.T * (rho / 4)
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, 8))
    rows = []
    for name, s in suite.items():
        import numpy as _np
        ratio = conv_ratio(s.scalar(lam))
        fn = jax.jit(lambda vv, s=s: s.apply_reversed(lambda u: l_mat @ u, vv))
        us = time_call(fn, v, iters=3)
        dil = base / ratio if _np.isfinite(ratio) and ratio > 0 else float("nan")
        note = "" if _np.isfinite(ratio) else ";DIVERGED(paper Sec 5.3)"
        rows.append((f"transforms/{name}", round(us, 1),
                     f"ratio={ratio:.3g};dilation_x={dil:.3g}{note}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
