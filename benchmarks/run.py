"""Benchmark harness: one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV rows (and nothing else on stdout).

Modules with cross-PR perf trajectories (bench_spectral, bench_stream,
bench_kernels, bench_distributed) additionally write machine-readable
``BENCH_<name>.json`` files at the repo root via
:func:`benchmarks.common.write_bench_json`.

``--check`` snapshots the committed BENCH_*.json files before running,
then diffs the freshly written payloads against them
(:func:`benchmarks.common.bench_regressions`) and exits non-zero on a
>25% key-metric regression — the perf-trajectory gate scripts/ci.sh
runs as a non-blocking stage.  ``--only spectral,stream`` restricts the
run to a subset of module tags (the names in the table below).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _snapshot_bench_files() -> dict[str, dict]:
    """The COMMITTED baselines: ``git show HEAD:BENCH_*.json`` when the
    repo is available, so repeated ``--check`` runs on one checkout keep
    diffing against the committed numbers instead of self-healing
    against the previous run's freshly rewritten files; the on-disk
    payload is only the fallback outside a git checkout."""
    import subprocess

    committed = {}
    for path in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")):
        fname = os.path.basename(path)
        try:
            blob = subprocess.run(
                ["git", "-C", REPO_ROOT, "show", f"HEAD:{fname}"],
                capture_output=True, text=True, timeout=30)
            if blob.returncode == 0:
                committed[fname] = json.loads(blob.stdout)
                continue
        except (OSError, subprocess.SubprocessError, json.JSONDecodeError):
            pass
        try:
            with open(path) as f:
                committed[fname] = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    return committed


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--check", action="store_true",
        help="diff freshly written BENCH_*.json key metrics against the "
             "committed files; exit 2 on a >25% regression")
    parser.add_argument(
        "--only", default=None, metavar="TAGS",
        help="comma-separated module tags to run (e.g. 'stream,spectral')")
    args = parser.parse_args(argv)

    from benchmarks import (bench_baselines, bench_cliques, bench_distributed,
                            bench_kernels, bench_linkpred, bench_mdp,
                            bench_serve, bench_series_degree, bench_spectral,
                            bench_stream, bench_transforms, bench_walks)
    from benchmarks.common import bench_regressions
    mods = [
        ("spectral", bench_spectral),
        ("stream", bench_stream),
        ("serve", bench_serve),
        ("distributed", bench_distributed),
        ("table2", bench_transforms),
        ("fig2_3", bench_mdp),
        ("fig4", bench_cliques),
        ("fig5", bench_linkpred),
        ("fig6", bench_series_degree),
        ("sec4.3", bench_walks),
        ("kernels", bench_kernels),
        ("appB_baselines", bench_baselines),
    ]
    if args.only:
        only = {t.strip() for t in args.only.split(",") if t.strip()}
        unknown = only - {t for t, _ in mods}
        if unknown:
            parser.error(f"unknown --only tags {sorted(unknown)}")
        mods = [(t, m) for t, m in mods if t in only]

    committed = _snapshot_bench_files() if args.check else {}

    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in mods:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # keep the harness robust
            failures += 1
            print(f"{tag}/ERROR,0,{type(e).__name__}:{e}", flush=True)

    if args.check:
        regressions = []
        for fname, old in sorted(committed.items()):
            path = os.path.join(REPO_ROOT, fname)
            try:
                with open(path) as f:
                    new = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue  # this run did not rewrite the file
            if new == old:
                continue  # not re-run (or byte-identical): nothing to diff
            for msg in bench_regressions(old, new):
                regressions.append(f"{fname}: {msg}")
        if regressions:
            print("BENCH REGRESSIONS (>25% on key metrics):",
                  file=sys.stderr)
            for msg in regressions:
                print(f"  {msg}", file=sys.stderr)
            sys.exit(2)
        print("bench check: no key-metric regressions", file=sys.stderr)

    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
