"""Benchmark harness: one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV rows (and nothing else on stdout).

Modules with cross-PR perf trajectories (bench_spectral, bench_stream,
bench_kernels) additionally write machine-readable ``BENCH_<name>.json``
files at the repo root via :func:`benchmarks.common.write_bench_json`."""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_baselines, bench_cliques, bench_distributed,
                            bench_kernels, bench_linkpred, bench_mdp,
                            bench_series_degree, bench_spectral, bench_stream,
                            bench_transforms, bench_walks)
    mods = [
        ("spectral", bench_spectral),
        ("stream", bench_stream),
        ("distributed", bench_distributed),
        ("table2", bench_transforms),
        ("fig2_3", bench_mdp),
        ("fig4", bench_cliques),
        ("fig5", bench_linkpred),
        ("fig6", bench_series_degree),
        ("sec4.3", bench_walks),
        ("kernels", bench_kernels),
        ("appB_baselines", bench_baselines),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in mods:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us},{derived}", flush=True)
        except Exception as e:  # keep the harness robust
            failures += 1
            print(f"{tag}/ERROR,0,{type(e).__name__}:{e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
