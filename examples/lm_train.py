"""Train a reduced assigned-pool architecture end to end on the synthetic
deterministic pipeline, with checkpoint/auto-resume (kill it mid-run and
rerun: it continues from the last checkpoint).

    PYTHONPATH=src python examples/lm_train.py
"""
from repro.launch.train import main

main(["--mode", "lm", "--arch", "granite-moe-1b-a400m", "--smoke",
      "--steps", "30", "--ckpt-dir", "/tmp/lm_train_ck",
      "--ckpt-every", "10", "--log-every", "5"])
