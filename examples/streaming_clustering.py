"""Streaming graph clustering with warm-started SPED sessions.

Walkthrough of the stream subsystem: admit several SBM graphs into a
multi-tenant StreamingService, tick them to convergence through ONE
compiled batched step, stream edge updates at them (small ones ride the
first-order incremental eigen-update path; heavy rewires trigger the
drift fallback into a warm re-solve), and read back cluster labels whose
ids stay stable across re-solves.

Run:  PYTHONPATH=src python examples/streaming_clustering.py
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import graphs
from repro.core.kmeans import cluster_agreement
from repro.stream import ServiceConfig, StreamingService

NUM_GRAPHS = 4
N, BLOCKS = 240, 4


def main() -> None:
    svc = StreamingService(ServiceConfig(
        k=6, num_clusters=BLOCKS, degree=9, steps_per_tick=25,
        lr=0.3, tol=5e-3, dilation_strength=6.0))

    print(f"== admitting {NUM_GRAPHS} SBM graphs (n={N}, {BLOCKS} blocks)")
    truth, gdict = {}, {}
    for i in range(NUM_GRAPHS):
        g, labels = graphs.sbm_graph(N, BLOCKS, p_in=0.25, p_out=0.01,
                                     seed=i)
        sid = f"tenant-{i}"
        svc.add_graph(sid, g, num_clusters=BLOCKS, edge_capacity=8192)
        truth[sid] = labels
        gdict[sid] = g
        print(f"   {sid}: {g.num_edges} edges "
              f"(planned degree={svc.session_info(sid)['degree']}, "
              f"tau={svc.session_info(sid)['tau']:.0f})")

    ticks = svc.run_until_converged(max_ticks=200)
    status = "converged" if svc.all_converged else "NOT converged"
    print(f"== {status} in {ticks} ticks, "
          f"{svc.compile_count} compiled step program(s)")
    for sid, labels in truth.items():
        acc = float(cluster_agreement(
            jnp.asarray(svc.labels(sid)), jnp.asarray(labels), BLOCKS))
        info = svc.session_info(sid)
        print(f"   {sid}: residual={info['residual']:.1e} "
              f"agreement={acc:.2f}")

    # ---- a small update: first-order incremental path ------------------
    sid = "tenant-0"
    before = svc.labels(sid)
    print("== small update (2 reweighted edges) ->", end=" ")
    src, dst, _ = svc.live_edges(sid)
    svc.apply_updates(sid, np.stack([src[:2], dst[:2]], 1), [1.5, 0.75],
                      mode="set")
    info = svc.session_info(sid)
    path = "incremental" if info["converged"] else "re-solve"
    print(f"{path} (fallbacks={info['fallbacks']})")

    # ---- a heavy rewire: drift fallback -> warm re-solve ---------------
    print("== heavy update (25% of edges deleted) ->", end=" ")
    src, dst, _ = svc.live_edges(sid)
    rng = np.random.default_rng(0)
    sel = rng.choice(len(src), size=len(src) // 4, replace=False)
    svc.apply_updates(sid, np.stack([src[sel], dst[sel]], 1),
                      np.zeros(len(sel)), mode="set")
    info = svc.session_info(sid)
    print(f"fallback={info['fallbacks'] == 1}, warm re-solve queued")
    t0 = info["ticks"]
    svc.run_until_converged(max_ticks=200)
    info = svc.session_info(sid)
    after = svc.labels(sid)
    stable = float(np.mean(np.asarray(before) == np.asarray(after)))
    print(f"   warm re-solve reconverged in {info['ticks'] - t0} ticks "
          f"(the thinned graph has smaller eigengaps than at admission, "
          f"so this is the hard case; benchmarks/bench_stream.py shows "
          f"the 1%-churn case at >=3x fewer iterations); stable label "
          f"ids for {stable:.0%} of nodes; compiled programs still "
          f"{svc.compile_count}")

    print("== evicting converged sessions")
    done = svc.evict_converged()
    for sid, summary in done.items():
        print(f"   {sid}: ticks={summary['ticks']} "
              f"solves={summary['solves']} "
              f"incremental={summary['incremental_updates']} "
              f"fallbacks={summary['fallbacks']}")

    # ---- panel caching: an evicted tenant re-admits warm ---------------
    sid = "tenant-1"
    summary = done[sid]
    print(f"== re-admitting {sid} from its cached panel")
    svc.add_graph(sid, gdict[sid], num_clusters=BLOCKS,
                  edge_capacity=8192, resume_panel=summary["panel"])
    svc.run_until_converged(max_ticks=50)
    info = svc.session_info(sid)
    print(f"   reconverged in {info['ticks']} tick(s) vs "
          f"{summary['ticks']} at cold admission "
          f"(residual={info['residual']:.1e})")


if __name__ == "__main__":
    main()
