"""Auto-tuned spectral clustering: probe -> plan -> dilate -> solve.

Instead of hand-picking the transform family, polynomial degree, and
dilation strength (and anchoring the scale to the loose Gershgorin
bound), let repro.spectral probe the spectrum with a few dozen matvecs
and plan the dilation per graph:

    PYTHONPATH=src python examples/planned_clustering.py
"""
import jax
import jax.numpy as jnp

from repro.core import ClusteringConfig, SolverConfig, spectral_cluster
from repro.core import graphs
from repro.core.kmeans import cluster_agreement
from repro.core.laplacian import spectral_radius_upper_bound
from repro import spectral

for name, (g, truth), k in [
    ("ring_of_cliques", graphs.ring_of_cliques(6, 20), 6),
    ("sbm", graphs.sbm_graph(300, 4, p_in=0.3, p_out=0.05, seed=0), 4),
]:
    probe, plan = spectral.probe_and_plan(g, k=k, key=jax.random.PRNGKey(0))
    rho_ub = float(spectral_radius_upper_bound(g))
    print(f"{name}: n={g.num_nodes} E={g.num_edges}")
    print(f"  probed lambda_max={plan.rho:.2f} (Gershgorin bound {rho_ub:.2f}, "
          f"{rho_ub / plan.rho:.2f}x looser)  probe cost={plan.probe_matvecs} matvecs")
    print(f"  plan: family={plan.family} degree={plan.degree} tau={plan.tau} "
          f"(probed bottom gap ({plan.lam_k:.2f}, {plan.lam_k1:.2f}), "
          f"predicted dilated gap ratio {plan.predicted_gap_ratio:.1f})")

    cfg = ClusteringConfig(
        num_clusters=k, transform="auto",
        solver=SolverConfig(method="mu_eg", lr=0.3, steps=600, eval_every=25),
        seed=0)
    labels, info = spectral_cluster(g, cfg)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), k))
    print(f"  spectral_cluster(transform='auto'): series={info['series']} "
          f"accuracy={acc:.3f}\n")
