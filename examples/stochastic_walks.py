"""The paper's Sec. 4.3, fully realized: unbiased random-walk estimation
of Laplacian powers driving the eigensolver — no full matvec ever
computed; only walkers on the edge incidence graph.

    PYTHONPATH=src python examples/stochastic_walks.py
"""
import jax
import jax.numpy as jnp

from repro.core import (SolverConfig, build_edge_incidence, laplacian_dense,
                        run_solver, spectral_radius_upper_bound)
from repro.core import graphs, metrics, walks
from repro.core.kmeans import cluster_agreement, kmeans

g, truth = graphs.clique_graph(96, 3, seed=0)
inc = build_edge_incidence(g)
rho = float(spectral_radius_upper_bound(g))
print(f"{g.num_nodes} nodes; incidence graph degree bound {inc.deg_star_inc}")

k = 4
coeffs = walks.lowdeg_negexp_coeffs(4, rho, tau=6.0 / rho)
print("low-degree -e^(-tau L) fit, power-basis coeffs:",
      [f"{c:.2e}" for c in coeffs])
op = walks.walk_polynomial_operator(g, inc, coeffs, lambda_star=0.0,
                                    num_walkers=4096, mode="importance")
L = laplacian_dense(g)
_, v_star = metrics.ground_truth_bottom_k(L, k)
cfg = SolverConfig(method="mu_eg", lr=0.05, steps=800, eval_every=100, k=k)
state, trace = run_solver(op, g.num_nodes, cfg, v_star=v_star,
                          stochastic=True)
print(f"subspace error from walks alone: "
      f"{float(trace.subspace_error[-1]):.4f}")
emb = state.v[:, 1:4]
emb = emb / jnp.maximum(jnp.linalg.norm(emb, axis=1, keepdims=True), 1e-12)
labels = kmeans(jax.random.PRNGKey(1), emb, 3).labels
print(f"cluster accuracy: "
      f"{float(cluster_agreement(labels, jnp.asarray(truth), 3)):.3f}")
