"""Proto-value functions for a 3-room grid-world MDP (paper Sec. 5.3).

The bottom-k eigenvectors of the state-transition graph Laplacian are the
proto-value functions (Mahadevan 2005).  SPED accelerates their
computation; the PVFs' sign structure recovers the room partition.

    PYTHONPATH=src python examples/mdp_protovalues.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SolverConfig, laplacian_dense, limit_neg_exp,
                        run_solver, spectral_radius_upper_bound)
from repro.core import graphs, metrics, operators

g, rooms = graphs.three_room_mdp(s=1, h=10)
print(f"grid world: {g.num_nodes} states, {g.num_edges} transitions")
L = laplacian_dense(g)
rho = float(spectral_radius_upper_bound(g))
k = 4
_, v_star = metrics.ground_truth_bottom_k(L, k)

series = limit_neg_exp(251)
op = operators.series_operator(series, operators.dense_matvec(L))
cfg = SolverConfig(method="mu_eg", lr=0.4, steps=1200, eval_every=50, k=k)
state, trace = run_solver(op, g.num_nodes, cfg, v_star=v_star)
print(f"subspace error: {float(trace.subspace_error[-1]):.5f}, "
      f"streak {int(trace.streak[-1])}/{k}")

# The 2nd/3rd PVFs separate the rooms: check sign-based room recovery
pvf = np.asarray(state.v)
fiedler = pvf[:, 1]
corr = abs(np.corrcoef(np.sign(fiedler), np.where(rooms == 1, 0.0,
                                                  np.sign(rooms - 1)))[0, 1])
print(f"|corr(sign(PVF_2), outer-vs-middle rooms)| = {corr:.3f}")
