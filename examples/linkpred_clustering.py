"""Clustering a probabilistically-completed graph (paper App. A.1).

Drop 20% of edges, predict them back with common-neighbors scores
(probabilistic weights), cluster the WEIGHTED Laplacian with SPED.

    PYTHONPATH=src python examples/linkpred_clustering.py
"""
import jax.numpy as jnp

from repro.core import ClusteringConfig, SolverConfig, spectral_cluster
from repro.core import graphs, linkpred
from repro.core.kmeans import cluster_agreement

g, truth = graphs.clique_graph(180, 3, seed=5)
gw = linkpred.complete_graph(g, drop_prob=0.2, seed=6)
print(f"dropped+repredicted 20% of {g.num_edges} edges -> "
      f"{gw.num_edges} weighted edges")

cfg = ClusteringConfig(
    num_clusters=3, transform="limit_neg_exp", degree=101,
    solver=SolverConfig(method="mu_eg", lr=0.4, steps=900, eval_every=100),
    seed=0)
labels, info = spectral_cluster(gw, cfg)
acc = float(cluster_agreement(labels, jnp.asarray(truth), 3))
print(f"clustering accuracy on the completed graph: {acc:.3f} "
      "(SPED is spectrum-only, so weighted graphs work unchanged)")
