"""Quickstart: spectral clustering with SPED (the paper in ~40 lines).

Builds a well-clustered graph, dilates its eigengaps with the paper's
limit-series approximation of -e^{-L}, runs the stochastic mu-EigenGame
solver to the bottom-k eigenvectors, k-means the embedding, and compares
convergence against the identity (no-transform) baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (ClusteringConfig, SolverConfig, spectral_cluster)
from repro.core import graphs
from repro.core.kmeans import cluster_agreement
from repro.core.solvers import steps_to_streak

g, truth = graphs.clique_graph(200, 4, seed=0)
print(f"graph: {g.num_nodes} nodes, {g.num_edges} edges, 4 planted cliques")

for transform, lr in [("identity", 2e-2), ("limit_neg_exp", 0.4)]:
    cfg = ClusteringConfig(
        num_clusters=4,
        transform=transform,
        degree=251,              # paper Fig. 6's winning degree
        auto_scale=False,        # paper-faithful: raw L
        solver=SolverConfig(method="mu_eg", lr=lr, steps=2500,
                            eval_every=25),
        seed=0)
    labels, info = spectral_cluster(g, cfg)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), 4))
    streak_at = steps_to_streak(info["trace"], cfg.num_clusters)
    print(f"{transform:14s} accuracy={acc:.3f} "
          f"full-eigenvector-streak at step {streak_at} "
          f"(-1 = not within budget)")
print("SPED reaches the ordered eigenvectors ~an order of magnitude "
      "sooner (paper Figs. 2-4).")
