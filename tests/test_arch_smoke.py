"""Per-architecture smoke tests: reduced same-family configs run one
train step (loss + grads) and a prefill+decode round trip on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only by
the dry-run (launch/dryrun.py), never allocated here."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # one train step per LM arch; excluded from scripts/ci.sh fast lane

from repro.configs import ARCHS, get_arch, smoke_config
from repro.models import model as model_lib
from repro.models.frontends import synthetic_frontend

ARCH_IDS = sorted(ARCHS)


def make_batch(cfg, batch=2, seq=24, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    batch_d = {"tokens": toks,
               "labels": jnp.roll(toks, -1, axis=1)}
    batch_d.update(synthetic_frontend(jax.random.fold_in(key, 7), cfg, batch))
    return batch_d


@pytest.fixture(scope="module")
def params_cache():
    return {}


def get_params(cfg, params_cache):
    if cfg.name not in params_cache:
        params_cache[cfg.name] = model_lib.init(jax.random.PRNGKey(1), cfg)
    return params_cache[cfg.name]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, params_cache):
    cfg = smoke_config(get_arch(arch))
    p = get_params(cfg, params_cache)
    batch = make_batch(cfg)

    def loss_fn(p):
        loss, metrics = model_lib.train_loss(p, cfg, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(p)
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    # rough sanity: early loss near ln(vocab)
    assert 0.0 < float(loss) < 2 * np.log(cfg.vocab_size) + 1
    flat, _ = jax.tree.flatten(grads)
    for g in flat:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch, params_cache):
    cfg = smoke_config(get_arch(arch))
    p = get_params(cfg, params_cache)
    b, s = 2, 16
    batch = make_batch(cfg, batch=b, seq=s)
    logits, state = model_lib.prefill(p, cfg, batch, max_seq=s + 4)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill NaN"
    tok = jnp.argmax(logits, axis=-1)[:, None]
    for _ in range(2):
        logits, state = model_lib.decode_step(p, cfg, state, tok)
        assert logits.shape == (b, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: decode NaN"
        tok = jnp.argmax(logits, axis=-1)[:, None]


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-1.2b"])
def test_prefill_matches_decode_ssm(arch, params_cache):
    """Chunked SSD prefill then decode == decoding every token from
    scratch (state handoff correctness)."""
    cfg = smoke_config(get_arch(arch))
    p = get_params(cfg, params_cache)
    b, s = 1, 8
    batch = make_batch(cfg, batch=b, seq=s, key=jax.random.PRNGKey(3))
    toks = batch["tokens"]
    # path A: prefill all s tokens, logits for last position
    logits_a, _ = model_lib.prefill(p, cfg, batch, max_seq=s + 2)
    # path B: decode token by token
    state = model_lib.init_caches(cfg, b, s + 2)
    for t in range(s):
        logits_b, state = model_lib.decode_step(p, cfg, state,
                                                toks[:, t: t + 1])
    # bf16 residual stream + different (mathematically equal) association
    # orders of the SSD recurrence -> a few % drift is expected
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=6e-2, atol=6e-2)
    # (exact state-handoff equality is asserted in f32 by
    # test_models_unit.py::test_ssd_chunked_matches_sequential_oracle;
    # here the bf16 residual stream may flip near-tied argmaxes)


@pytest.mark.parametrize("arch", [
    "qwen3-4b",
    "granite-moe-1b-a400m",
    pytest.param(
        "deepseek-v2-236b",
        marks=pytest.mark.xfail(
            reason="bf16 rounding differs between the prefill and decode "
            "computation orders, which can flip near-tied top-k routing "
            "decisions; across 4 MoE layers the flipped experts produce "
            "legitimately different logits.  The equivalence DOES hold "
            "in f32 (max |Δ| ~9e-3 at this seed) and the MLA absorption "
            "algebra is asserted exactly by "
            "test_mla_absorbed_decode_matches_train_f32.",
            strict=False)),
])
def test_prefill_matches_decode_attn(arch, params_cache):
    cfg = smoke_config(get_arch(arch))
    p = get_params(cfg, params_cache)
    b, s = 1, 8
    batch = make_batch(cfg, batch=b, seq=s, key=jax.random.PRNGKey(4))
    toks = batch["tokens"]
    logits_a, _ = model_lib.prefill(p, cfg, batch, max_seq=s + 2)
    state = model_lib.init_caches(cfg, b, s + 2)
    for t in range(s):
        logits_b, state = model_lib.decode_step(p, cfg, state,
                                                toks[:, t: t + 1])
    # bf16 residual stream: absorbed-MLA decode and expanded-MLA prefill
    # are algebraically identical (verified in f32 unit test) but round
    # differently
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=6e-2, atol=6e-2)
    assert np.array_equal(np.argmax(logits_a, -1), np.argmax(logits_b, -1))


def test_mla_absorbed_decode_matches_train_f32():
    """MLA weight-absorption algebra: decode == train attention in f32."""
    from repro.models import attention as attn_mod
    cfg = smoke_config(get_arch("deepseek-v2-236b"))
    key = jax.random.PRNGKey(0)
    p = attn_mod.init_attention(key, cfg)
    b, s = 1, 6
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, cfg.d_model), jnp.float32) * 0.1
    out_train, _ = attn_mod.mla_train(p, cfg, x)
    cache = attn_mod.init_mla_cache(cfg, b, s)
    outs = []
    for t in range(s):
        o, cache = attn_mod.mla_decode(p, cfg, x[:, t: t + 1], cache)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(out_train, out_dec, rtol=1e-2, atol=5e-3)


def test_all_archs_param_counts_plausible():
    """Full configs' analytic param counts are in the advertised ballpark."""
    expect = {
        "qwen1.5-32b": (28e9, 40e9),
        "qwen3-4b": (3e9, 5e9),
        "starcoder2-15b": (12e9, 18e9),
        "minitron-8b": (7e9, 10.5e9),
        "whisper-small": (0.15e9, 0.5e9),
        "zamba2-1.2b": (1.0e9, 1.7e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "llava-next-mistral-7b": (6e9, 8.5e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_arch("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
