"""Spectral probing & dilation planner (repro.spectral).

Probe accuracy is checked against dense ``eigh`` oracles on small
SBM/ring/clique graphs; planner properties (monotonicity, budget, cap)
against synthetic exact probes.  Everything randomized carries the
``stochastic`` marker and a FIXED PRNG seed — the suite is deterministic
run-to-run, the marker documents which assertions rest on concentration
rather than algebraic identities.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphs, laplacian_dense, make_edge_list
from repro.core.laplacian import (minibatch_laplacian_matvec,
                                  spectral_radius_upper_bound)
from repro.core import metrics, operators
from repro import spectral
from repro.spectral import plan as plan_mod

SEED = 0


def _graph_cases():
    return {
        "sbm": graphs.sbm_graph(200, 4, p_in=0.3, p_out=0.05, seed=0)[0],
        "ring": graphs.ring_of_cliques(5, 12)[0],
        "clique": graphs.clique_graph(120, 4, seed=0)[0],
    }


# ---------------------------------------------------------------------------
# probes vs exact eigh
# ---------------------------------------------------------------------------

@pytest.mark.stochastic
@pytest.mark.parametrize("name,g", _graph_cases().items())
def test_slq_lambda_max_matches_eigh(name, g):
    lam = np.linalg.eigvalsh(np.asarray(laplacian_dense(g)))
    probe = spectral.probe_graph(g, key=jax.random.PRNGKey(SEED))
    est = float(probe.lambda_max)
    # Lanczos converges at the spectrum edges first: a 24-step probe is
    # tight at the top; the residual correction may overshoot slightly.
    assert 0.9 * lam[-1] <= est <= 1.1 * lam[-1]
    # ...and never looser than the Gershgorin bound the planner caps by.
    assert est <= float(spectral_radius_upper_bound(g)) * 1.01


@pytest.mark.stochastic
@pytest.mark.parametrize("name,g", _graph_cases().items())
def test_slq_density_mass_and_mean(name, g):
    lam = np.linalg.eigvalsh(np.asarray(laplacian_dense(g)))
    probe = spectral.probe_graph(g, key=jax.random.PRNGKey(SEED))
    edges, mass = spectral.spectral_density(probe, num_bins=16)
    assert mass.shape == (16,)
    # total estimated eigenvalue count ~ n
    np.testing.assert_allclose(mass.sum(), g.num_nodes, rtol=0.15)
    # first moment of the density ~ mean eigenvalue (= tr L / n)
    mids = 0.5 * (edges[:-1] + edges[1:])
    np.testing.assert_allclose(
        float((mids * mass).sum() / mass.sum()), float(lam.mean()), rtol=0.15)
    # the SLQ trace shortcut agrees with tr L = sum of degrees
    np.testing.assert_allclose(float(probe.trace), float(lam.sum()), rtol=0.1)


@pytest.mark.stochastic
def test_bottom_edge_localizer_sees_the_cut():
    """ring_of_cliques: q tiny eigenvalues, then a jump to ~clique size.
    The counting-function localizer must place lambda_{q+1} in the upper
    group and keep the estimated relative gap macroscopic."""
    q, m = 5, 12
    g, _ = graphs.ring_of_cliques(q, m)
    probe = spectral.probe_graph(g, key=jax.random.PRNGKey(SEED))
    lam_k, lam_k1 = spectral.bottom_edge(probe, q)
    assert lam_k1 >= 0.5 * m  # upper group located
    assert lam_k1 - lam_k >= 0.25 * float(probe.lambda_max)


def test_probe_from_eigenvalues_is_exact():
    lam = np.array([0.0, 0.1, 0.2, 5.0, 6.0, 7.0], np.float32)
    probe = spectral.probe_from_eigenvalues(lam)
    assert float(probe.lambda_max) == pytest.approx(7.0)
    assert float(probe.trace) == pytest.approx(float(lam.sum()))
    lam_k, lam_k1 = spectral.bottom_edge(probe, 3)
    assert lam_k == pytest.approx(0.2, abs=1e-6)
    assert lam_k1 == pytest.approx(5.0, abs=1e-6)
    assert spectral.eigenvalue_count(probe, 1.0) == pytest.approx(3.0)


@pytest.mark.stochastic
def test_lanczos_breakdown_is_clean():
    """num_steps > n must not corrupt the quadrature (sticky breakdown):
    Ritz values stay within the true spectrum's hull."""
    g = make_edge_list(np.array([[0, 1], [1, 2], [2, 3], [0, 3]]), 4)
    lam = np.linalg.eigvalsh(np.asarray(laplacian_dense(g)))
    probe = spectral.probe_graph(g, key=jax.random.PRNGKey(SEED),
                                 num_probes=2, num_steps=16)
    assert float(probe.lambda_max) <= lam[-1] * 1.05 + 1e-5
    assert float(jnp.max(probe.ritz)) <= lam[-1] + 1e-3
    assert float(jnp.min(probe.ritz)) >= -1e-3


@pytest.mark.stochastic
def test_hutchinson_unbiased_exact_and_minibatch():
    """Hutchinson trace under the MINIBATCH operator matches tr(L):
    probe and batch draws are independent, so E[z' L_b z] = tr L."""
    g, _ = graphs.sbm_graph(80, 4, p_in=0.4, p_out=0.05, seed=1)
    tr = float(2.0 * jnp.sum(g.weight))  # tr L = sum of weighted degrees
    exact = spectral.hutchinson_trace(
        lambda v: operators.edge_matvec(g)(v), g.num_nodes,
        jax.random.PRNGKey(SEED), num_probes=128)
    np.testing.assert_allclose(float(exact), tr, rtol=0.1)

    e = g.num_edges
    batch = 128

    def keyed_mv(k, v):
        sel = jax.random.randint(k, (batch,), 0, e)
        return minibatch_laplacian_matvec(
            g.src[sel], g.dst[sel], g.weight[sel], v, e)

    mb = spectral.hutchinson_trace(
        keyed_mv, g.num_nodes, jax.random.PRNGKey(SEED + 1),
        num_probes=256, keyed=True)
    np.testing.assert_allclose(float(mb), tr, rtol=0.1)


@pytest.mark.stochastic
def test_padded_probe_matches_unpadded():
    """A node/edge capacity-padded operator with the n_real mask probes
    the same spectrum as the raw graph (the streaming-store contract)."""
    from repro.core.laplacian import pad_edge_list
    from repro.spectral.probes import probe_edge_arrays

    g, _ = graphs.ring_of_cliques(4, 8)
    gp = pad_edge_list(g, 128)
    raw = spectral.probe_graph(g, key=jax.random.PRNGKey(SEED))
    padded = probe_edge_arrays(
        gp.src, gp.dst, gp.weight, jax.random.PRNGKey(SEED),
        jnp.asarray(g.num_nodes, jnp.int32),
        num_nodes=64,  # node capacity > real n
        num_probes=4, num_steps=24)
    np.testing.assert_allclose(
        float(padded.lambda_max), float(raw.lambda_max), rtol=0.05)
    lam = np.linalg.eigvalsh(np.asarray(laplacian_dense(g)))
    assert 0.9 * lam[-1] <= float(padded.lambda_max) <= 1.1 * lam[-1]


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def _synthetic_probe(lam_k, lam_k1, rho=40.0, k=4):
    """Exact probe with k eigenvalues at <= lam_k, the rest above lam_k1."""
    bottom = np.linspace(0.0, lam_k, k)
    top = np.linspace(lam_k1, rho, 8)
    return spectral.probe_from_eigenvalues(
        np.concatenate([bottom, top]).astype(np.float32))


def test_planner_monotone_in_gap():
    """Larger probed gap (lam_k, rho fixed) => no larger degree, and no
    stronger tau.  Exercises the decision rule via the explicit-gap
    override so localizer candidate selection can't alias the sweep."""
    degrees, taus = [], []
    for lam_k1 in np.linspace(2.1, 30.0, 12):
        plan = spectral.plan_dilation(
            _synthetic_probe(2.0, float(lam_k1)), k=4, budget=96,
            lam_k=2.0, lam_k1=float(lam_k1))
        degrees.append(plan.degree)
        taus.append(plan.tau)
    assert all(d2 <= d1 for d1, d2 in zip(degrees, degrees[1:]))
    assert all(t2 <= t1 for t1, t2 in zip(taus, taus[1:]))


def test_planner_identity_when_gap_is_wide():
    plan = spectral.plan_dilation(_synthetic_probe(0.5, 30.0), k=4, budget=96)
    assert plan.family == "identity"
    assert plan.degree == 1
    assert plan.lambda_star > plan.rho  # Eq. 8 reversal stays valid
    assert plan.operator_scale == pytest.approx(plan.lambda_star)


def test_planner_respects_budget_and_parity():
    for budget in (7, 15, 41, 96):
        plan = spectral.plan_dilation(
            _synthetic_probe(0.2, 1.0), k=4, budget=budget)
        assert plan.degree <= budget
        if plan.family == "limit_neg_exp":
            assert plan.degree % 2 == 1  # paper Table 2: l odd


def test_planner_wanted_decay_cap():
    """tau * lam_k / rho stays <= ~MAX_WANTED_DECAY: over-dilation must
    not crush the wanted directions' solver signal."""
    plan = spectral.plan_dilation(_synthetic_probe(20.0, 21.0), k=4, budget=96)
    assert plan.family != "identity"  # gap is tiny
    assert plan.tau * plan.lam_k / plan.rho <= plan_mod.MAX_WANTED_DECAY + 1e-6


def test_planner_fallback_without_probe():
    plan = spectral.plan_dilation(None, k=4, budget=96, rho_fallback=30.0)
    assert plan.source == "fallback"
    assert plan.rho == pytest.approx(30.0)
    assert plan.family == "limit_neg_exp"  # unknown gap => assume hard case
    s = spectral.series_from_plan(plan)
    assert s.degree == plan.degree


def test_planner_degenerate_graph():
    plan = spectral.plan_dilation(None, k=2, budget=96)
    assert plan.family == "identity"
    # the plan must still materialize into a usable series
    s = spectral.series_from_plan(plan)
    v = jnp.ones((3, 2))
    out = s.apply_reversed(lambda u: jnp.zeros_like(u), v)
    assert out.shape == v.shape


@pytest.mark.stochastic
def test_series_from_plan_preserves_order():
    """The planned operator's top-k eigenvectors are the bottom-k of L
    (reversal + monotone series => order preservation)."""
    g, _ = graphs.ring_of_cliques(4, 8)
    L = laplacian_dense(g)
    lam = np.linalg.eigvalsh(np.asarray(L))
    _, plan = spectral.probe_and_plan(g, k=4, key=jax.random.PRNGKey(SEED))
    s = spectral.series_from_plan(plan)
    f = np.asarray(s.reversed_scalar(jnp.asarray(lam)))
    assert np.all(np.diff(f) <= 1e-5)  # decreasing in lam: bottom-k on top


@pytest.mark.stochastic
def test_streaming_service_probed_rho():
    """Admission probes a tighter rho than the Gershgorin bound (denser
    graphs ~2x) and still converges; probing off falls back to the
    bound exactly."""
    from repro.stream.service import ServiceConfig, StreamingService

    g, _ = graphs.sbm_graph(150, 3, p_in=0.4, p_out=0.05, seed=0)
    base = dict(k=4, num_clusters=3, degree=7, steps_per_tick=25,
                lr=0.3, tol=5e-3, dilation_strength=6.0)
    svc = StreamingService(ServiceConfig(**base, probe_spectrum=True))
    svc.add_graph("a", g)
    info = svc.session_info("a")
    assert info["rho"] < 0.8 * info["rho_ub"]  # probe beat the bound
    lam = np.linalg.eigvalsh(np.asarray(laplacian_dense(g)))
    assert info["rho"] >= 0.9 * lam[-1]  # ...without undershooting
    svc.run_until_converged(max_ticks=300)
    assert svc.all_converged

    off = StreamingService(ServiceConfig(**base, probe_spectrum=False))
    off.add_graph("a", g)
    info_off = off.session_info("a")
    assert info_off["rho"] == info_off["rho_ub"]  # jit-time fallback


@pytest.mark.stochastic
def test_planned_operator_end_to_end():
    """planned_operator reaches the exact bottom-k subspace."""
    g, _ = graphs.ring_of_cliques(4, 8)
    k = 4
    op, plan = operators.planned_operator(g, k=k, key=jax.random.PRNGKey(SEED))
    lam, v_star = metrics.ground_truth_bottom_k(
        jnp.asarray(laplacian_dense(g)), k)
    from repro.core import solvers
    cfg = solvers.SolverConfig(
        method="mu_eg", lr=plan.suggested_lr(0.3), steps=600,
        eval_every=50, k=k, seed=SEED)
    _, trace = solvers.run_solver(op, g.num_nodes, cfg, v_star=v_star)
    assert float(trace.subspace_error[-1]) < 0.01
