"""Property battery for the skew-proof chunked blocking layouts.

The CSR chunk layout (``ops.build_node_blocking``: blocks own
``ceil(bucket / block_e)`` chunks, only the TOTAL chunk count is
pow2-snapped, and a scalar-prefetched chunk->block map drives the
kernel) exists for skewed degree distributions — power-law graphs whose
hub blocks would otherwise inflate every block to the worst bucket's
padding.  This file drives power-law samples through the layout and
asserts the structural contracts the kernels rely on:

  * every live half-edge is materialized exactly once, at its
    destination block (single-device AND model-sharded layouts);
  * the chunk->block map is well formed (monotone, covers every block,
    pow2 tail extends the last block as inert padding);
  * padded work never exceeds the legacy uniform layout's, and beats it
    >= 2x on a genuinely skewed graph;
  * all-padding model shards are exact-zero operators on both the
    kernel and segment row paths.

Runs as a seeded battery (the CI image ships without hypothesis); when
hypothesis IS importable the same checks also run generatively.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphs
from repro.kernels.edge_spmm import ops as es_ops
from repro.kernels.edge_spmm import ref as es_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI image has no hypothesis
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.pallas

SEEDS = list(range(1, 21))


def _skewed_case(seed: int):
    """Power-law graph + DISTINCT weights (exact multiset comparisons)
    + a random block size, with some zero (capacity-padding) slots."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(60, 400))
    g = graphs.power_law_graph(
        n, avg_degree=float(rng.uniform(2.0, 12.0)), alpha=2.5, seed=seed)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = (np.arange(1, len(src) + 1, dtype=np.float32)
         * rng.uniform(0.5, 1.5)).astype(np.float32)
    w[rng.uniform(size=len(src)) < 0.15] = 0.0
    block_n = int(rng.choice([8, 16, 32, 64]))
    return src, dst, w, n, block_n


def _half_edge_multiset(src, dst, w):
    """Expected live half-edges {(u, o, w)}: two per live edge."""
    live = w != 0.0
    s, d, ww = src[live], dst[live], w[live]
    return sorted(zip(np.concatenate([s, d]).tolist(),
                      np.concatenate([d, s]).tolist(),
                      np.concatenate([ww, ww]).tolist()))


def _blocking_half_edges(nb: es_ops.NodeBlocking, row_offset: int = 0):
    """Live half-edges the CSR layout materialized, in global row ids
    (``row_offset`` globalizes a model shard's local coordinates)."""
    cb = np.asarray(nb.chunk_block)[: nb.num_chunks]
    ul = np.asarray(nb.u_local).reshape(nb.num_chunks, nb.block_e)
    ot = np.asarray(nb.other).reshape(nb.num_chunks, nb.block_e)
    wt = np.asarray(nb.weight).reshape(nb.num_chunks, nb.block_e)
    out = []
    for c in range(nb.num_chunks):
        live = wt[c] != 0.0
        rows = ul[c, live] + int(cb[c]) * nb.block_n + row_offset
        out.extend(zip(rows.tolist(), ot[c, live].tolist(),
                       wt[c, live].tolist()))
    return sorted(out)


def _half_edge_counts(src, dst, w, block_n: int, nb: int):
    """Per-block live half-edge counts (the uniform-layout baseline)."""
    live = w != 0.0
    u = np.concatenate([src[live], dst[live]])
    return np.bincount(u // block_n, minlength=nb)


# ---------------------------------------------------------------------------
# the checks (seed -> assertions); parametrized battery + optional
# hypothesis drivers below
# ---------------------------------------------------------------------------

def _check_chunk_block_well_formed(seed: int):
    src, dst, w, n, block_n = _skewed_case(seed)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    cb = np.asarray(nb.chunk_block)
    blocks = nb.padded_nodes // nb.block_n
    assert cb.shape == (nb.num_chunks + 1,)
    assert nb.num_chunks == es_ops.next_pow2(nb.num_chunks)
    # monotone chunk runs, every block owns >= 1 chunk, and the pow2
    # padding tail (sentinel included) extends the LAST block's run
    assert (np.diff(cb) >= 0).all()
    assert np.array_equal(np.unique(cb), np.arange(blocks))
    raw = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n,
                                     snap_chunks=False)
    assert (cb[raw.num_chunks:] == blocks - 1).all()
    # padding chunks carry no live half-edges
    wt = np.asarray(nb.weight).reshape(nb.num_chunks, nb.block_e)
    assert (wt[raw.num_chunks:] == 0.0).all()


def _check_chunked_covers_each_half_edge_once(seed: int):
    src, dst, w, n, block_n = _skewed_case(seed)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    assert _blocking_half_edges(nb) == _half_edge_multiset(src, dst, w)


def _check_padded_work_le_uniform(seed: int):
    """Raw CSR padded work (sum of per-block ceils) never exceeds the
    raw uniform layout's (every block pays the max ceil); the pow2
    total-snap then costs < 2x on top.  Snapped-to-snapped comparison
    is NOT monotone on near-uniform degree counts (total-snap vs the
    uniform layout's per-block snap), so the invariant is raw-to-raw —
    the >= 2x win on skewed graphs is asserted separately."""
    src, dst, w, n, block_n = _skewed_case(seed)
    raw = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n,
                                     snap_chunks=False)
    counts = _half_edge_counts(src, dst, w, block_n,
                               raw.padded_nodes // block_n)
    assert raw.padded_half_edges <= es_ops.uniform_padded_half_edges(
        counts, raw.block_e, snap_chunks=False)
    snapped = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    assert snapped.padded_half_edges < 2 * raw.padded_half_edges


def _check_chunked_kernel_matches_segment(seed: int):
    src, dst, w, n, block_n = _skewed_case(seed)
    rng = np.random.default_rng(seed + 10_000)
    k = int(rng.integers(1, 6))
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    got = es_ops.edge_spmm_blocked(nb, v, interpret=True)
    want = es_ref.edge_spmm(jnp.asarray(src), jnp.asarray(dst),
                            jnp.asarray(w), v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def _check_model_sharded_slices_consistent(seed: int):
    """Shard s materializes exactly the half-edges destined to its row
    range [s*R, (s+1)*R) — in local coordinates — and its degree slice
    is the global degree vector's slice; the union over shards covers
    every live half-edge exactly once."""
    src, dst, w, n, block_n = _skewed_case(seed)
    num_shards = int(np.random.default_rng(seed + 1).choice([2, 4, 8]))
    mb = es_ops.build_model_sharded_blocking(src, dst, w, n, num_shards,
                                             block_n=block_n)
    rows = mb.rows_per_shard
    assert mb.num_chunks == es_ops.next_pow2(mb.num_chunks)
    want_all = _half_edge_multiset(src, dst, w)
    got_all = []
    deg_full = np.zeros(mb.padded_nodes, np.float32)
    np.add.at(deg_full, src, w)
    np.add.at(deg_full, dst, w)
    for s in range(num_shards):
        got = _blocking_half_edges(mb.shard(s), row_offset=s * rows)
        want = [he for he in want_all
                if s * rows <= he[0] < (s + 1) * rows]
        assert got == sorted(want), s
        got_all.extend(got)
        np.testing.assert_allclose(
            np.asarray(mb.deg[s]), deg_full[s * rows:(s + 1) * rows],
            rtol=1e-6, atol=1e-6)
    assert sorted(got_all) == want_all


def _check_model_sharded_rows_match_dense(seed: int):
    """Concatenated per-shard owned rows (kernel AND segment paths)
    == L v on the skewed graph."""
    from repro.core import laplacian as lap
    src, dst, w, n, block_n = _skewed_case(seed)
    rng = np.random.default_rng(seed + 20_000)
    k = int(rng.integers(1, 5))
    num_shards = int(rng.choice([2, 4]))
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    mb = es_ops.build_model_sharded_blocking(src, dst, w, n, num_shards,
                                             block_n=block_n)
    rows = mb.rows_per_shard
    want = np.asarray(lap.edge_matvec_arrays(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w), v))
    ab = jnp.asarray([1.0, 0.0], jnp.float32)
    for use_kernel in (False, True):
        out = np.concatenate([
            np.asarray(es_ops.model_local_rows(
                mb.u_local[s], mb.other[s], mb.weight[s],
                mb.chunk_block[s], mb.deg[s], v, ab,
                jnp.asarray(s * rows, jnp.int32),
                block_n=mb.block_n, block_e=mb.block_e,
                num_chunks=mb.num_chunks, padded_nodes=mb.padded_nodes,
                use_kernel=use_kernel, interpret=True))
            for s in range(num_shards)])
        np.testing.assert_allclose(out[:n], want, rtol=2e-4, atol=2e-4,
                                   err_msg=f"use_kernel={use_kernel}")


# ---------------------------------------------------------------------------
# seeded battery (always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_chunk_block_well_formed(seed):
    _check_chunk_block_well_formed(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_chunked_covers_each_half_edge_once(seed):
    _check_chunked_covers_each_half_edge_once(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_padded_work_le_uniform(seed):
    _check_padded_work_le_uniform(seed)


@pytest.mark.parametrize("seed", SEEDS[:8])
def test_chunked_kernel_matches_segment(seed):
    _check_chunked_kernel_matches_segment(seed)


@pytest.mark.parametrize("seed", SEEDS[:10])
def test_model_sharded_slices_consistent(seed):
    _check_model_sharded_slices_consistent(seed)


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_model_sharded_rows_match_dense(seed):
    _check_model_sharded_rows_match_dense(seed)


def test_skew_reduction_on_power_law():
    """On a genuinely skewed graph (alpha = 2.5, hub blocks), the CSR
    chunk layout walks >= 2x fewer padded half-edge slots than the
    legacy uniform layout — the acceptance bar the skew bench rows
    measure at scale."""
    g = graphs.power_law_graph(4096, avg_degree=8.0, alpha=2.5, seed=0)
    nb = es_ops.build_node_blocking(g.src, g.dst, g.weight, g.num_nodes,
                                    block_n=256)
    counts = _half_edge_counts(np.asarray(g.src), np.asarray(g.dst),
                               np.asarray(g.weight), 256,
                               nb.padded_nodes // 256)
    uniform = es_ops.uniform_padded_half_edges(counts, nb.block_e)
    assert uniform / nb.padded_half_edges >= 2.0, \
        (uniform, nb.padded_half_edges)


def test_model_all_padding_shard_inert():
    """A model shard owning only empty rows is a zero operator (exact
    zeros, no NaN) on BOTH row paths: every edge lands in shard 0, so
    shards 1..3 hold pure padding."""
    rng = np.random.default_rng(5)
    n, block_n, num_shards = 64, 8, 4
    rows_owned = 16  # rows per shard with these sizes
    src = rng.integers(0, rows_owned, 40)
    dst = rng.integers(0, rows_owned, 40)
    keep = src != dst
    w = rng.uniform(0.5, 1.5, keep.sum()).astype(np.float32)
    mb = es_ops.build_model_sharded_blocking(
        src[keep], dst[keep], w, n, num_shards, block_n=block_n)
    assert mb.rows_per_shard == rows_owned
    v = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    ab = jnp.asarray([1.0, 0.0], jnp.float32)
    for s in (1, 3):
        assert (np.asarray(mb.weight[s]) == 0.0).all()
        for use_kernel in (False, True):
            out = np.asarray(es_ops.model_local_rows(
                mb.u_local[s], mb.other[s], mb.weight[s],
                mb.chunk_block[s], mb.deg[s], v, ab,
                jnp.asarray(s * rows_owned, jnp.int32),
                block_n=mb.block_n, block_e=mb.block_e,
                num_chunks=mb.num_chunks, padded_nodes=mb.padded_nodes,
                use_kernel=use_kernel, interpret=True))
            assert not np.isnan(out).any()
            np.testing.assert_array_equal(out, 0.0)


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_chunked_covers_property(seed):
        _check_chunked_covers_each_half_edge_once(seed)
        _check_chunk_block_well_formed(seed)
        _check_padded_work_le_uniform(seed)

    @given(st.integers(1, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_model_sharded_property(seed):
        _check_model_sharded_slices_consistent(seed)
