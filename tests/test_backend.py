"""Backend equivalence: pallas(interpret) == segment on every
solver-facing operator (repro.core.backend).

The acceptance contract of the backend layer: for the same inputs each
backend is deterministic, and the pallas kernels (run in interpret mode
on CPU — the exact kernel code path, minus Mosaic) match the segment
gather/scatter to <= 1e-5 max-abs on weighted, capacity-padded, and
non-block-aligned graphs, for the plain matvec, the fused series step,
and the fused mu-EG step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, graphs, operators, solvers
from repro.core import laplacian as lap
from repro.core.series import (cheb_log, limit_neg_exp, taylor_log,
                               taylor_neg_exp)

pytestmark = pytest.mark.pallas

TOL = 1e-5


def _rand_graph(seed: int, n: int, e: int) -> lap.EdgeList:
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], axis=1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.uniform(0.1, 2.0, size=len(edges)).astype(np.float32)
    return lap.make_edge_list(edges, n, weights=w)


def _panel(seed: int, n: int, k: int) -> jax.Array:
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(n, k)), jnp.float32)


# weighted / capacity-padded / non-aligned (n, k, E not block multiples)
CASES = {
    "weighted": lambda: _rand_graph(0, 96, 300),
    "capacity_padded": lambda: lap.pad_edge_list(_rand_graph(1, 96, 300), 512),
    "non_aligned": lambda: _rand_graph(2, 301, 517),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_matvec_equivalence(case):
    g = CASES[case]()
    v = _panel(3, g.num_nodes, 5)
    seg = operators.edge_matvec(g, backend="segment")(v)
    pal = operators.edge_matvec(g, backend="pallas")(v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL


@pytest.mark.parametrize("case", sorted(CASES))
def test_matvec_equivalence_node_blocked(case):
    """Forced blocking exercises the scalable kernel on small graphs
    (block_n far below n, non-divisible on the non_aligned case)."""
    g = CASES[case]()
    blk = backend.blocking_for(g, block_n=64)
    v = _panel(4, g.num_nodes, 6)
    seg = operators.edge_matvec(g, backend="segment")(v)
    pal = operators.edge_matvec(g, backend="pallas", blocking=blk)(v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL


def test_matvec_edgeless_graph():
    """Zero-edge graphs (a supported streaming-admission state) must
    return zeros on BOTH backends — the pallas wrapper pads an inert
    block instead of emitting a zero-size grid."""
    g = lap.make_edge_list(np.zeros((0, 2), np.int64), 40)
    v = _panel(16, 40, 3)
    for b in ("segment", "pallas"):
        out = operators.edge_matvec(g, backend=b)(v)
        np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_matvec_1d_column_agree():
    g = CASES["non_aligned"]()
    mv = operators.edge_matvec(g, backend="pallas")
    v = _panel(5, g.num_nodes, 1)
    np.testing.assert_allclose(mv(v[:, 0]), mv(v)[:, 0], atol=TOL)


def test_auto_resolves_and_rejects():
    assert backend.resolve_backend("auto") in ("segment", "pallas")
    assert backend.resolve_backend("segment") == "segment"
    with pytest.raises(ValueError):
        backend.resolve_backend("cuda")


def _unit_radius(g: lap.EdgeList, target: float = 1.5) -> lap.EdgeList:
    """Rescale weights so the Gershgorin radius is `target` — the regime
    every production series runs in (the planner/auto_scale normalize L),
    and the only one where taylor_log converges at all."""
    rho = float(lap.spectral_radius_upper_bound(g))
    return g._replace(weight=g.weight * (target / rho))


@pytest.mark.parametrize("series_fn", [
    lambda: limit_neg_exp(7, scale=0.4),
    lambda: taylor_neg_exp(5),
    lambda: taylor_log(5),
    lambda: cheb_log(12, rho=1.5),
], ids=["limit_neg_exp", "taylor_neg_exp", "taylor_log", "cheb_log"])
@pytest.mark.parametrize("case", ["weighted", "non_aligned"])
def test_fused_series_equivalence(series_fn, case):
    """series_operator with the fused pallas step == classic segment
    recurrence, for every fused series family."""
    g = _unit_radius(CASES[case]())
    s = series_fn()
    v = _panel(6, g.num_nodes, 4)
    seg = operators.edge_series_operator(g, s, backend="segment")(v)
    pal = operators.edge_series_operator(g, s, backend="pallas")(v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL


def test_fused_series_node_blocked():
    g = CASES["capacity_padded"]()
    s = limit_neg_exp(9, scale=0.3)
    blk = backend.blocking_for(g, block_n=32)
    v = _panel(7, g.num_nodes, 3)
    seg = operators.edge_series_operator(g, s, backend="segment")(v)
    pal = operators.edge_series_operator(g, s, backend="pallas",
                                         blocking=blk)(v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL


def test_poly_step_edges_matches_dense_poly_step():
    """The edge-list extension of laplacian_poly.poly_step == its dense
    oracle on the graph Laplacian."""
    from repro.kernels.laplacian_poly import ops as lp_ops, ref as lp_ref

    g = CASES["weighted"]()
    blk = backend.blocking_for(g, block_n=32)
    u = _panel(8, g.num_nodes, 4)
    got = lp_ops.poly_step_edges(blk, u, 0.07, interpret=True)
    want = lp_ref.poly_step(lap.laplacian_dense(g), u, 0.07)
    np.testing.assert_allclose(got, want, atol=TOL)


def test_mu_eg_step_backend_equivalence():
    v = _panel(9, 300, 6)
    v = v / jnp.linalg.norm(v, axis=0, keepdims=True)
    av = _panel(10, 300, 6)
    st = solvers.SolverState(v=v, step=jnp.zeros((), jnp.int32))
    seg = solvers.make_step_fn("mu_eg", "segment")(st, av, 0.05)
    pal = solvers.make_step_fn("mu_eg", "pallas")(st, av, 0.05)
    assert float(jnp.max(jnp.abs(seg.v - pal.v))) <= TOL
    assert int(seg.step) == int(pal.step) == 1


def test_minibatch_matvec_1d_2d_weighting():
    """The minibatch matvec weights 1-D and (N, 1) inputs identically
    (regression for the old atleast_2d(diff.T).T contortion; also
    asserted with hypothesis sweeps in test_laplacian when available)."""
    g = CASES["weighted"]()
    rng = np.random.default_rng(15)
    sel = jnp.asarray(rng.integers(0, g.num_edges, 32), jnp.int32)
    v = jnp.asarray(rng.normal(size=(g.num_nodes,)), jnp.float32)
    out1 = lap.minibatch_laplacian_matvec(
        g.src[sel], g.dst[sel], g.weight[sel], v, g.num_edges)
    out2 = lap.minibatch_laplacian_matvec(
        g.src[sel], g.dst[sel], g.weight[sel], v[:, None], g.num_edges)
    assert out1.shape == (g.num_nodes,) and out2.shape == (g.num_nodes, 1)
    np.testing.assert_allclose(out1, out2[:, 0], rtol=1e-6, atol=1e-6)
    # full edge set => scale E_total/B == 1 => exact L @ v
    full = lap.minibatch_laplacian_matvec(
        g.src, g.dst, g.weight, v, g.num_edges)
    np.testing.assert_allclose(full, lap.laplacian_matvec(g, v),
                               rtol=1e-5, atol=1e-5)


def test_minibatch_operator_backend_equivalence():
    """Same key => same sampled edges; only the SpMM implementation
    differs between backends."""
    g = CASES["weighted"]()
    s = limit_neg_exp(5, scale=0.4)
    v = _panel(11, g.num_nodes, 4)
    key = jax.random.PRNGKey(42)
    seg = operators.minibatch_operator(g, s, 64, backend="segment")(key, v)
    pal = operators.minibatch_operator(g, s, 64, backend="pallas")(key, v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL


def test_run_solver_backend_equivalence():
    """Whole-solve equivalence: identical traces and panels for a short
    run under each backend (matvec AND mu-EG step fused on pallas)."""
    g = CASES["weighted"]()
    s = limit_neg_exp(7, scale=0.4)
    outs = {}
    for b in ("segment", "pallas"):
        op = operators.edge_series_operator(g, s, backend=b)
        cfg = solvers.SolverConfig(method="mu_eg", lr=0.3, steps=10,
                                   eval_every=5, k=4, seed=0, backend=b)
        state, trace = solvers.run_solver(op, g.num_nodes, cfg)
        outs[b] = (state.v, trace.subspace_error)
    assert float(jnp.max(jnp.abs(outs["segment"][0] - outs["pallas"][0]))) <= TOL


def test_planned_operator_backend():
    g, _ = graphs.ring_of_cliques(4, 8)
    op_s, plan_s = operators.planned_operator(
        g, k=4, key=jax.random.PRNGKey(0), backend="segment")
    op_p, plan_p = operators.planned_operator(
        g, k=4, key=jax.random.PRNGKey(0), backend="pallas")
    assert plan_s.family == plan_p.family
    v = _panel(12, g.num_nodes, 4)
    assert float(jnp.max(jnp.abs(op_s(v) - op_p(v)))) <= TOL


def test_probe_backend_equivalence():
    g = CASES["weighted"]()
    from repro.spectral import probes
    ps = probes.probe_graph(g, backend="segment")
    pp = probes.probe_graph(g, backend="pallas")
    assert abs(float(ps.lambda_max) - float(pp.lambda_max)) <= 1e-4
    np.testing.assert_allclose(ps.ritz, pp.ritz, atol=1e-4)


def test_sharded_matvec_backend_equivalence():
    from jax.sharding import Mesh

    from repro.core import distributed

    g = CASES["weighted"]()
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    gp = distributed.pad_edges_for_mesh(g, mesh.shape["data"])
    v = _panel(13, g.num_nodes, 4)
    seg = distributed.sharded_laplacian_matvec(mesh, backend="segment")(
        gp.src, gp.dst, gp.weight, v)
    pal = distributed.sharded_laplacian_matvec(mesh, backend="pallas")(
        gp.src, gp.dst, gp.weight, v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL


def test_streaming_tick_backend_equivalence():
    """One tick program per backend over the same admitted graph: the
    panels and residuals must agree (node-blocked kernel + fused mu-EG
    step vs the vmapped segment tick)."""
    from repro.stream.service import ServiceConfig, StreamingService

    g, _ = graphs.sbm_graph(120, 3, p_in=0.35, p_out=0.03, seed=1)
    common = dict(k=5, num_clusters=3, degree=7, steps_per_tick=5, lr=0.3,
                  seed=0)
    seg = StreamingService(ServiceConfig(backend="segment", **common))
    pal = StreamingService(ServiceConfig(backend="pallas", tick_block_n=32,
                                         **common))
    for svc in (seg, pal):
        svc.add_graph("a", g)
    rs, rp = seg.tick(), pal.tick()
    assert abs(rs["a"] - rp["a"]) <= TOL
    vs = seg._sessions["a"].v
    vp = pal._sessions["a"].v
    assert float(jnp.max(jnp.abs(vs - vp))) <= TOL
    # updates invalidate + rebuild the blocking; ticks stay equivalent
    for svc in (seg, pal):
        svc.apply_updates("a", [[0, 5], [1, 7]], [1.0, 1.0])
    seg.tick(), pal.tick()
    assert pal._sessions["a"].blocking is not None
    vs = seg._sessions["a"].v
    vp = pal._sessions["a"].v
    assert float(jnp.max(jnp.abs(vs - vp))) <= TOL
    assert pal.compile_count == 1  # one program for the whole episode


def test_blocking_determinism_and_padding():
    """Same graph => bitwise-identical blocking; zero-weight (capacity
    padding) slots are dropped, not bucketed."""
    g = CASES["weighted"]()
    gp = lap.pad_edge_list(g, 512)
    b1 = backend.blocking_for(g, block_n=32)
    b2 = backend.blocking_for(g, block_n=32)
    bp = backend.blocking_for(gp, block_n=32)
    for a, b in zip(b1[:4], b2[:4]):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(b1[:4], bp[:4]):
        np.testing.assert_array_equal(a, b)  # padding slots invisible
    assert b1.num_chunks == bp.num_chunks


@pytest.mark.slow
@pytest.mark.parametrize("block_n", [16, 64, 256])
@pytest.mark.parametrize("block_e", [128, 256])
def test_block_sweep_equivalence(block_n, block_e):
    """Blocking layout sweep on a larger skewed graph (slow lane)."""
    rng = np.random.default_rng(7)
    n, e = 1500, 6000
    # skewed: hub nodes concentrate edges in a few buckets
    hub = rng.integers(0, 32, e)
    far = rng.integers(0, n, e)
    edges = np.stack([hub, far], axis=1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.uniform(0.5, 1.5, len(edges)).astype(np.float32)
    g = _unit_radius(lap.make_edge_list(edges, n, weights=w))
    blk = backend.blocking_for(g, block_n=block_n, block_e=block_e)
    v = _panel(14, n, 4)
    seg = operators.edge_matvec(g, backend="segment")(v)
    pal = operators.edge_matvec(g, backend="pallas", blocking=blk)(v)
    assert float(jnp.max(jnp.abs(seg - pal))) <= TOL
