"""End-to-end system tests: training loops (both modes), fault-injected
resume, and mesh-path equivalences."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

pytestmark = pytest.mark.slow  # long-running; excluded from scripts/ci.sh fast lane


def test_sped_training_driver_converges(tmp_path):
    from repro.launch.train import main
    main(["--mode", "sped", "--steps", "250", "--nodes", "150",
          "--clusters", "3", "--ckpt-dir", str(tmp_path / "ck")])


def test_lm_training_driver_smoke(tmp_path):
    from repro.launch.train import main
    main(["--mode", "lm", "--arch", "qwen3-4b", "--smoke", "--steps", "6",
          "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3",
          "--log-every", "100"])
    # fault injection: "crash" happened; rerun must resume from step 6
    main(["--mode", "lm", "--arch", "qwen3-4b", "--smoke", "--steps", "9",
          "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3",
          "--log-every", "100"])
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path / "ck")) == 9


def test_lm_training_with_grad_compression(tmp_path):
    from repro.launch.train import main
    main(["--mode", "lm", "--arch", "granite-moe-1b-a400m", "--smoke",
          "--steps", "4", "--compress-grads", "--log-every", "100"])


def test_moe_shard_map_matches_reference_path():
    """The shard_map MoE fast path (1-device mesh) == the global-jit
    grouped reference (no mesh)."""
    from repro.configs import get_arch, smoke_config
    from repro.models import moe as moe_mod
    cfg = smoke_config(get_arch("granite-moe-1b-a400m"))
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.3
    ref, aux_ref = moe_mod.moe_ffn(p, cfg, x)  # no mesh -> fallback
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        got, aux = jax.jit(lambda p, x: moe_mod.moe_ffn(p, cfg, x))(p, x)
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(aux, aux_ref, rtol=1e-4, atol=1e-5)


def test_decode_step_under_mesh_matches_no_mesh():
    """Whole-model decode under a 1-device mesh (CP attention + fori
    cache) == plain path."""
    from repro.configs import get_arch, smoke_config
    from repro.models import model as model_lib
    cfg = smoke_config(get_arch("qwen3-4b"))
    p = model_lib.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    state = model_lib.init_caches(cfg, b, s + 1)
    logits_ref = None
    for t in range(s):
        logits_ref, state = model_lib.decode_step(p, cfg, state,
                                                  toks[:, t: t + 1])
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        state = model_lib.init_caches(cfg, b, s + 1)
        step = jax.jit(lambda p, st, t: model_lib.decode_step(p, cfg, st, t))
        for t in range(s):
            logits, state = step(p, state, toks[:, t: t + 1])
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(logits_ref), rtol=3e-2, atol=3e-2)


def test_elastic_remesh_then_restore(tmp_path):
    """Simulated node loss: save at mesh A, rebuild the elastic mesh,
    restore, and keep training (shapes are sharding-agnostic numpy)."""
    from repro.train import checkpoint as ckpt
    from repro.train import fault
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(str(tmp_path / "ck"), 5, tree)
    mesh, dropped = fault.elastic_mesh(model_axis=16)  # 1 device here
    with mesh:
        restored, _, step = ckpt.restore_with_fallback(
            str(tmp_path / "ck"), jax.tree.map(jnp.zeros_like, tree))
    assert step == 5
    np.testing.assert_allclose(restored["w"], tree["w"])


def test_microbatched_train_step_matches_full_batch():
    """Gradient accumulation (dryrun's --microbatch) == single-batch step."""
    from repro.configs import get_arch, smoke_config
    from repro.launch.dryrun import build_train_step
    from repro.models import model as model_lib
    from repro.train import optimizer as opt_lib
    cfg = smoke_config(get_arch("qwen3-4b"))
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1e9,
                             weight_decay=0.0)
    params = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(ocfg, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    p1, _, m1 = build_train_step(cfg, ocfg, microbatches=1)(
        params, opt_state, batch)
    p4, _, m4 = build_train_step(cfg, ocfg, microbatches=4)(
        params, opt_state, batch)
    # each microbatch has its own loss normalization (per-token mean per
    # slice == global mean here since slices are equal-sized)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-3)
    flat1 = jax.tree.leaves(p1)
    flat4 = jax.tree.leaves(p4)
    for a, b in zip(flat1, flat4):
        # bf16 forward reduction order differs between slicings
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=3e-3)


def test_bf16_moments_still_converge():
    from repro.train import optimizer as opt
    cfg = opt.OptConfig(lr=0.05, warmup_steps=0, total_steps=500,
                        weight_decay=0.0, moment_dtype="bfloat16")
    params = {"w": jnp.asarray([2.0, -3.0, 1.0])}
    state = opt.init(cfg, params)
    assert state.mu["w"].dtype == jnp.bfloat16
    for _ in range(500):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = opt.apply(cfg, state, params, g)
    assert float(jnp.sum(params["w"] ** 2)) < 1e-2
