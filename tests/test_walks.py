"""Random-walk estimator of Laplacian powers (paper Sec. 4.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_edge_incidence, laplacian_dense
from repro.core import graphs, walks


@pytest.fixture(scope="module")
def setup():
    g, _ = graphs.ring_of_cliques(3, 4)
    inc = build_edge_incidence(g)
    L = np.asarray(laplacian_dense(g))
    return g, inc, L


@pytest.mark.parametrize("power", [1, 2, 3])
def test_importance_estimator_unbiased(setup, power):
    g, inc, L = setup
    wb = walks.sample_walks(jax.random.PRNGKey(0), inc, 120_000, 3)
    est = np.asarray(walks.estimate_power_dense(wb, g, inc, power, g.num_nodes))
    want = np.linalg.matrix_power(L, power)
    rel = np.linalg.norm(est - want) / np.linalg.norm(want)
    assert rel < 0.05, f"L^{power} rel err {rel}"


@pytest.mark.parametrize("power", [1, 2])
def test_rejection_estimator_unbiased(setup, power):
    """The paper-faithful Eq. 14 rejection scheme (higher variance)."""
    g, inc, L = setup
    wb = walks.sample_walks(jax.random.PRNGKey(1), inc, 200_000, 3)
    est = np.asarray(walks.estimate_power_dense(
        wb, g, inc, power, g.num_nodes, mode="rejection",
        key=jax.random.PRNGKey(2)))
    want = np.linalg.matrix_power(L, power)
    rel = np.linalg.norm(est - want) / np.linalg.norm(want)
    assert rel < 0.35, f"L^{power} rel err {rel}"


def test_importance_lower_variance_than_rejection(setup):
    """Beyond-paper claim: HT weighting Rao-Blackwellizes the accept coin."""
    g, inc, L = setup
    want = L @ L
    errs = {}
    for mode in ["importance", "rejection"]:
        sq = 0.0
        for t in range(6):
            wb = walks.sample_walks(jax.random.PRNGKey(10 + t), inc, 20_000, 2)
            est = np.asarray(walks.estimate_power_dense(
                wb, g, inc, 2, g.num_nodes, mode=mode,
                key=jax.random.PRNGKey(100 + t)))
            sq += np.sum((est - want) ** 2)
        errs[mode] = sq
    assert errs["importance"] < errs["rejection"]


def test_walk_probabilities_are_proper(setup):
    g, inc, _ = setup
    wb = walks.sample_walks(jax.random.PRNGKey(3), inc, 1000, 3)
    # log p decreasing along the walk, bounded by p_min (Eq. 14)
    assert bool(jnp.all(wb.logp[:, 1] <= wb.logp[:, 0] + 1e-6))
    log_pmin = -2 * np.log(inc.deg_star_inc) - np.log(g.num_edges)
    assert bool(jnp.all(wb.logp[:, 1] >= log_pmin - 1e-5))


def test_alpha_values_follow_table1(setup):
    """alpha factors are products of {+-1, 2} inner products — all walks
    on the incidence graph have nonzero alpha."""
    g, inc, _ = setup
    wb = walks.sample_walks(jax.random.PRNGKey(4), inc, 5000, 3)
    assert bool(jnp.all(wb.alpha != 0.0))
    # one-step alphas must be exactly +-1 or 2
    a1 = np.asarray(wb.alpha[:, 1])
    assert set(np.unique(a1)).issubset({-1.0, 1.0, 2.0})


def test_walk_operator_converges_in_solver(setup):
    """End-to-end: walk-estimated low-degree operator drives mu-EG to the
    bottom eigenvectors."""
    from repro.core import SolverConfig, metrics, run_solver
    g, inc, L = setup
    rho = float(2 * jnp.max(jnp.asarray(L).diagonal()))
    coeffs = walks.lowdeg_negexp_coeffs(4, rho, tau=6.0 / rho)
    op = walks.walk_polynomial_operator(g, inc, coeffs, 0.0, num_walkers=4096)
    k = 3
    _, v_star = metrics.ground_truth_bottom_k(jnp.asarray(L), k)
    cfg = SolverConfig(method="mu_eg", lr=0.05, steps=600, eval_every=50,
                       k=k, seed=0)
    _, tr = run_solver(op, g.num_nodes, cfg, v_star=v_star, stochastic=True)
    assert float(tr.subspace_error[-1]) < 0.05
