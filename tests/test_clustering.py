"""End-to-end spectral clustering + link prediction (paper Secs. 5, A.1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClusteringConfig, SolverConfig, spectral_cluster
from repro.core import graphs
from repro.core.kmeans import cluster_agreement, kmeans


def test_kmeans_separates_blobs():
    key = jax.random.PRNGKey(0)
    centers = jnp.asarray([[0.0, 0.0], [5.0, 5.0], [-5.0, 5.0]])
    pts = jnp.concatenate([
        centers[i] + 0.3 * jax.random.normal(jax.random.fold_in(key, i), (40, 2))
        for i in range(3)
    ])
    truth = jnp.repeat(jnp.arange(3), 40)
    res = kmeans(key, pts, 3)
    assert float(cluster_agreement(res.labels, truth, 3)) > 0.99


@pytest.mark.parametrize("transform", ["limit_neg_exp", "cheb_log"])
def test_spectral_cluster_recovers_cliques(transform):
    g, truth = graphs.clique_graph(160, 4, seed=3)
    cfg = ClusteringConfig(
        num_clusters=4, transform=transform, degree=64 if transform ==
        "cheb_log" else 251,
        solver=SolverConfig(method="mu_eg", lr=0.4, steps=600, eval_every=100),
        seed=0)
    labels, info = spectral_cluster(g, cfg)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), 4))
    assert acc > 0.95, f"{transform}: accuracy {acc}"


def test_spectral_cluster_minibatch_stochastic():
    g, truth = graphs.clique_graph(120, 3, seed=4)
    cfg = ClusteringConfig(
        num_clusters=3, transform="limit_neg_exp", degree=51,
        estimation="minibatch", batch_edges=512,
        solver=SolverConfig(method="mu_eg", lr=0.1, steps=1500, eval_every=250),
        seed=0)
    labels, _ = spectral_cluster(g, cfg)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), 3))
    assert acc > 0.9, f"stochastic accuracy {acc}"


def test_weighted_graph_clustering_linkpred():
    """Paper App. A.1: clustering survives probabilistic edge completion."""
    from repro.core import linkpred
    g, truth = graphs.clique_graph(120, 3, seed=5)
    g_completed = linkpred.complete_graph(g, drop_prob=0.2, seed=6)
    assert float(jnp.min(g_completed.weight)) >= 0.0
    cfg = ClusteringConfig(
        num_clusters=3, transform="limit_neg_exp", degree=101,
        solver=SolverConfig(method="mu_eg", lr=0.4, steps=800, eval_every=100),
        seed=0)
    labels, _ = spectral_cluster(g_completed, cfg)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), 3))
    assert acc > 0.9, f"linkpred accuracy {acc}"


def test_exact_reference_pipeline():
    from repro.core import exact_cluster_reference
    g, truth = graphs.clique_graph(100, 4, seed=7)
    labels = exact_cluster_reference(g, 4)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), 4))
    assert acc > 0.95


def test_walks_with_auto_transform_skips_probe():
    """Regression: transform="auto" + estimation="walks" used to pay a
    ~96-matvec probe-and-plan whose plan the walks branch then
    discarded; now the probe is skipped entirely (plan is None) and the
    pipeline still runs."""
    from repro.core import ClusteringConfig, SolverConfig, spectral_cluster

    g, truth = graphs.ring_of_cliques(3, 6)
    labels, info = spectral_cluster(g, ClusteringConfig(
        num_clusters=3, transform="auto", estimation="walks", degree=6,
        num_walkers=512,
        solver=SolverConfig(steps=40, eval_every=20, lr=0.1)))
    assert info["plan"] is None
    assert labels.shape == (g.num_nodes,)
