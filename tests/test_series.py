"""Spectrum-transform series (paper Sec. 4.2, Table 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (see pyproject.toml [test] extra)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    cheb_log, cheb_neg_exp, identity_series, laplacian_dense, limit_neg_exp,
    taylor_log, taylor_neg_exp, with_lambda_star,
)
from repro.core import graphs


@pytest.fixture(scope="module")
def small_graph():
    g, _ = graphs.ring_of_cliques(3, 6)
    return g, laplacian_dense(g)


def eig_apply(series, L, V):
    """Oracle: apply the series' scalar map through eigendecomposition."""
    lam, vecs = jnp.linalg.eigh(L)
    return (vecs * series.scalar(lam)[None, :]) @ (vecs.T @ V)


SERIES = [
    limit_neg_exp(51),
    limit_neg_exp(251),
    taylor_neg_exp(11),
    taylor_log(31, eps=0.05),
    cheb_neg_exp(32, rho=30.0),
    cheb_log(32, rho=30.0),
]


@pytest.mark.parametrize("s", SERIES, ids=lambda s: s.name)
def test_apply_matches_scalar_map(small_graph, s):
    """matrix-free apply == scalar map through eigh (eigenvector preserving)."""
    g, L = small_graph
    V = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 3))
    got = s.apply(lambda u: L @ u, V)
    want = eig_apply(s, L, V)
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(got / scale, want / scale, atol=5e-4)


def test_limit_series_converges_to_exp():
    lam = jnp.linspace(0.0, 10.0, 50)
    for d, tol in [(51, 0.5), (251, 0.12)]:
        err = jnp.max(jnp.abs(limit_neg_exp(d).scalar(lam) - (-jnp.exp(-lam))))
        assert float(err) < tol


def test_limit_series_monotone_everywhere():
    """Odd-degree limit series is monotone increasing on ALL of R (the
    property that makes it the paper's most robust series, Fig. 6)."""
    lam = jnp.linspace(-5.0, 600.0, 2001)
    f = limit_neg_exp(251).scalar(lam)
    assert bool(jnp.all(jnp.diff(f) >= -1e-5 * jnp.maximum(jnp.abs(f[1:]), 1.0)))


def test_taylor_log_matches_log_within_radius():
    """Convergent for spectrum within (0, 2-eps) (paper Sec. 5.3 caveat)."""
    lam = jnp.linspace(0.2, 1.7, 40)
    s = taylor_log(101, eps=0.05)
    err = jnp.max(jnp.abs(s.scalar(lam) - jnp.log(lam + 0.05)))
    assert float(err) < 1e-2


def test_taylor_log_diverges_outside_radius():
    lam = jnp.asarray(4.0)  # |lam - (1-eps)| > 1 -> divergence
    s = taylor_log(101, eps=0.05)
    val = float(jnp.abs(s.scalar(lam)))
    assert (val > 1e3) or np.isnan(val)


def test_chebyshev_beats_taylor_at_same_degree():
    """Beyond-paper claim: cheb needs far lower degree than Taylor."""
    rho = 30.0
    lam = jnp.linspace(0.0, rho, 200)
    target = -jnp.exp(-lam)
    cheb_err = jnp.max(jnp.abs(cheb_neg_exp(16, rho=rho).scalar(lam) - target))
    taylor_err = jnp.max(jnp.abs(taylor_neg_exp(17).scalar(lam) - target))
    assert float(cheb_err) < 1e-2
    assert float(cheb_err) < float(taylor_err) * 1e-2


def test_reversal_turns_bottom_into_top(small_graph):
    """Eq. (8): ordering of reversed transformed spectrum is flipped."""
    g, L = small_graph
    lam = jnp.linalg.eigvalsh(L)
    for s in [limit_neg_exp(51), with_lambda_star(identity_series(), float(lam[-1]) * 1.01)]:
        rev = s.reversed_scalar(lam)  # lam ascending -> rev must descend
        assert bool(jnp.all(jnp.diff(rev) <= 1e-5))


@given(st.integers(1, 100), st.floats(0.05, 0.95))
@settings(max_examples=25, deadline=None)
def test_limit_series_dilates_bottom_gaps(seed, frac):
    """Property: for spectra with lam_bottom << rho, the limit series
    improves the convergence ratio rho_range / gap (paper Sec. 3)."""
    rng = np.random.default_rng(seed)
    bottom = np.sort(rng.uniform(0.0, 1.0, size=4))
    bulk = rng.uniform(20.0, 40.0, size=8)
    lam = jnp.asarray(np.sort(np.concatenate([bottom, bulk])), jnp.float32)
    s = limit_neg_exp(251, scale=float(frac * 8.0 / lam[-1]))
    f = jnp.sort(s.scalar(lam))
    gap_before = (lam[1] - lam[0]) / (lam[-1] - lam[0])
    gap_after = (f[1] - f[0]) / (f[-1] - f[0])
    assert float(gap_after) >= float(gap_before) * 0.99


def test_stochastic_apply_uses_independent_keys():
    """apply_stochastic folds a distinct key into every inner matvec."""
    seen = []

    def keyed_mv(key, u):
        seen.append(key)
        return u

    s = limit_neg_exp(5)
    v = jnp.ones((4, 2))
    # trace eagerly (no jit) so the hook records traced keys
    s.apply_stochastic(keyed_mv, jax.random.PRNGKey(0), v)
    assert len(seen) == 1  # fori_loop traces once; key is fold_in(i) inside
