"""The unified SolveProgram layer (repro.core.program) and the adaptive
scheduling built on it.

Pins the refactor's contracts:
  * the deduped operator helpers (core.operators / core.metrics /
    stream.updates) are EXACTLY the closures stream.service used to
    hand-roll;
  * run_solver is a thin wrapper over program.run_program;
  * per-session lr / dilation-scale scheduling is traced — the
    (class, degree, layout, occupancy, multiplier) compile-cache key
    space stays on snapped/pow2 grids (the PR 4 logarithmic guarantee);
  * converged sessions cost ZERO device work per tick;
  * evicted tenants re-admit through panel caching and reconverge in
    fewer ticks;
  * the residual-decay tick scheduler reaches fleet convergence in
    fewer program invocations than round-robin at equal quality.
"""
import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphs, metrics, operators, program, solvers
from repro.core import laplacian as lap
from repro.core.series import limit_neg_exp
from repro.stream import updates
from repro.stream.service import ServiceConfig, StreamingService


def _rand_graph(seed: int, n: int, e: int) -> lap.EdgeList:
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], axis=1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.uniform(0.1, 2.0, size=len(edges)).astype(np.float32)
    return lap.make_edge_list(edges, n, weights=w)


def _panel(seed: int, n: int, k: int) -> jax.Array:
    v = jnp.asarray(
        np.random.default_rng(seed).normal(size=(n, k)), jnp.float32)
    q, _ = jnp.linalg.qr(v)
    return q


# ---------------------------------------------------------------------------
# deduped helpers == the service's old private closures (satellite 1)
# ---------------------------------------------------------------------------

_edge_mv = lap.edge_matvec_arrays


@functools.partial(jax.jit, static_argnames=("degree",))
def _legacy_op_apply(src, dst, w, v, c, degree):
    """Verbatim copy of the old stream.service._op_apply closure."""
    def body(_, u):
        return u - c * _edge_mv(src, dst, w, u)
    return jax.lax.fori_loop(0, degree, body, v)


@functools.partial(jax.jit, static_argnames=("degree",))
def _legacy_op_residual(src, dst, w, v, c, degree):
    av = _legacy_op_apply(src, dst, w, v, c, degree)
    return metrics.panel_residual(v, av)


@jax.jit
def _legacy_anchor_estimate(src, dst, w, v):
    return updates.estimate_from_panel(
        lambda x: _edge_mv(src, dst, w, x), v)


def test_dilated_matvec_matches_legacy_closure():
    g = _rand_graph(0, 50, 180)
    v = _panel(1, 50, 4)
    for degree in (1, 7):
        want = _legacy_op_apply(g.src, g.dst, g.weight, v, 0.03, degree)
        got = operators.dilated_matvec_arrays(
            g.src, g.dst, g.weight, v, 0.03, degree)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dilated_residual_matches_legacy_closure():
    g = _rand_graph(2, 50, 180)
    v = _panel(3, 50, 4)
    want = _legacy_op_residual(g.src, g.dst, g.weight, v, 0.02, 7)
    got = operators.dilated_panel_residual(
        g.src, g.dst, g.weight, v, 0.02, 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_anchor_estimate_matches_legacy_closure():
    g = _rand_graph(4, 50, 180)
    v = _panel(5, 50, 4)
    want = _legacy_anchor_estimate(g.src, g.dst, g.weight, v)
    got = updates.anchor_estimate_arrays(g.src, g.dst, g.weight, v)
    np.testing.assert_array_equal(np.asarray(got.lam), np.asarray(want.lam))
    np.testing.assert_array_equal(np.asarray(got.v), np.asarray(want.v))
    assert float(got.drift) == float(want.drift) == 0.0


def test_operator_residual_is_panel_residual_of_application():
    g = _rand_graph(6, 40, 120)
    v = _panel(7, 40, 3)
    mv = operators.edge_matvec(g)
    np.testing.assert_array_equal(
        np.asarray(metrics.operator_residual(mv, v)),
        np.asarray(metrics.panel_residual(v, mv(v))))


# ---------------------------------------------------------------------------
# run_solver is a thin wrapper over the unified loop
# ---------------------------------------------------------------------------

def test_run_solver_routes_through_run_program():
    g = _rand_graph(8, 60, 200)
    rho = float(lap.spectral_radius_upper_bound(g))
    s = limit_neg_exp(7, scale=1.0 / rho)
    op = operators.edge_series_operator(g, s)
    cfg = solvers.SolverConfig(method="mu_eg", lr=0.3, steps=20,
                               eval_every=10, k=4, seed=3)
    st_a, tr_a = solvers.run_solver(op, g.num_nodes, cfg)
    st_b, tr_b = program.run_program(op, g.num_nodes, cfg)
    np.testing.assert_array_equal(np.asarray(st_a.v), np.asarray(st_b.v))
    np.testing.assert_array_equal(np.asarray(tr_a.subspace_error),
                                  np.asarray(tr_b.subspace_error))


def test_tick_segment_matches_per_session_chunks():
    """One batched tick program == per-session run_chunk loops, with
    DIFFERENT per-session dilation scales and learning rates (the
    traced inputs one compiled program serves)."""
    gs_ = [_rand_graph(10 + i, 40, 150) for i in range(3)]
    cap = max(g.num_edges for g in gs_)
    gs_ = [lap.pad_edge_list(g, cap) for g in gs_]
    vs = jnp.stack([_panel(20 + i, 40, 4) for i in range(3)])
    cs = jnp.asarray([0.01, 0.02, 0.04], jnp.float32)
    lrs = jnp.asarray([0.1, 0.3, 0.5], jnp.float32)
    sched = program.StepSchedule(method="mu_eg", degree=5, steps=3)
    fn = program.build_tick_program(sched)
    # chunks=2: the traced multiplier runs 2 x 3 steps in one program
    out_v, out_r = fn(
        jnp.stack([g.src for g in gs_]),
        jnp.stack([g.dst for g in gs_]),
        jnp.stack([g.weight for g in gs_]),
        vs, cs, lrs, jnp.asarray(2, jnp.int32))
    step_fn = solvers.STEP_FNS["mu_eg"]
    for i, g in enumerate(gs_):
        opv = operators.dilated_operator_arrays(
            g.src, g.dst, g.weight, cs[i], 5)
        st = solvers.SolverState(v=vs[i], step=jnp.zeros((), jnp.int32))
        st, res = jax.jit(
            lambda s: program.run_chunk(opv, step_fn, s, lrs[i], 6))(st)
        assert float(jnp.max(jnp.abs(out_v[i] - st.v))) <= 1e-5
        assert abs(float(out_r[i]) - float(res)) <= 1e-5


def test_tick_per_session_chunk_vector_freezes_each_budget():
    """A (G,) chunk vector runs each session exactly its OWN budget —
    session i with budget c_i matches an independent run of c_i * steps
    solver steps, while the one program executes max(c) chunks (the
    per-session freeze mask behind the per-session tick multipliers)."""
    gs_ = [_rand_graph(30 + i, 40, 150) for i in range(3)]
    cap = max(g.num_edges for g in gs_)
    gs_ = [lap.pad_edge_list(g, cap) for g in gs_]
    vs = jnp.stack([_panel(40 + i, 40, 4) for i in range(3)])
    cs = jnp.asarray([0.01, 0.02, 0.04], jnp.float32)
    lrs = jnp.asarray([0.1, 0.3, 0.5], jnp.float32)
    budgets = [1, 2, 3]
    sched = program.StepSchedule(method="mu_eg", degree=5, steps=3)
    fn = program.build_tick_program(sched)
    out_v, out_r = fn(
        jnp.stack([g.src for g in gs_]),
        jnp.stack([g.dst for g in gs_]),
        jnp.stack([g.weight for g in gs_]),
        vs, cs, lrs, jnp.asarray(budgets, jnp.int32))
    step_fn = solvers.STEP_FNS["mu_eg"]
    for i, g in enumerate(gs_):
        opv = operators.dilated_operator_arrays(
            g.src, g.dst, g.weight, cs[i], 5)
        st = solvers.SolverState(v=vs[i], step=jnp.zeros((), jnp.int32))
        st, res = jax.jit(lambda s, n: program.run_chunk(
            opv, step_fn, s, lrs[i], n),
            static_argnums=1)(st, 3 * budgets[i])
        assert float(jnp.max(jnp.abs(out_v[i] - st.v))) <= 1e-5, i
        # frozen sessions keep the residual measured at their LAST live
        # chunk; the independent run measures at the same step count
        assert abs(float(out_r[i]) - float(res)) <= 1e-5, i


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_schedule_from_plan_identity_and_exp():
    from repro.spectral.plan import plan_dilation

    # wide probed gap -> identity family -> degree-1 unit-scale program
    ident = plan_dilation(None, k=4, budget=15, rho_fallback=10.0,
                          lam_k=0.0, lam_k1=8.0)
    assert ident.family == "identity"
    s = program.StepSchedule.from_plan(ident, steps=10, base_lr=0.4)
    assert s.degree == 1
    assert s.lr == pytest.approx(0.4)  # unit-normalized program form
    c = program.dilation_scale(ident, s.degree)
    assert c == pytest.approx(1.0 / ident.lambda_star)
    # narrow gap -> limit series at the planner degree
    dil = plan_dilation(None, k=4, budget=15, rho_fallback=10.0,
                        lam_k=1.0, lam_k1=1.2)
    assert dil.family == "limit_neg_exp"
    s2 = program.StepSchedule.from_plan(dil, steps=10, base_lr=0.4)
    assert s2.degree == dil.degree and s2.degree % 2 == 1
    assert program.dilation_scale(dil, s2.degree) == pytest.approx(
        dil.tau / (dil.rho * s2.degree))


def test_session_lr_varies_with_plan():
    """The per-session lr is genuinely plan-driven: tenants whose
    wanted spread the dilation decayed hardest take larger (capped)
    steps; tenants with the spread intact keep the base lr."""
    from repro.spectral.plan import plan_dilation

    mild = plan_dilation(None, k=4, budget=15, rho_fallback=10.0,
                         lam_k=0.05, lam_k1=0.2)
    strong = plan_dilation(None, k=4, budget=15, rho_fallback=10.0,
                           lam_k=2.0, lam_k1=2.3)
    lr_mild = program.session_lr(mild, 0.3)
    lr_strong = program.session_lr(strong, 0.3)
    assert lr_strong > lr_mild >= 0.3
    assert lr_strong <= 0.3 * program.LR_BOOST_CAP
    assert 0.0 < program.wanted_scale(strong) < program.wanted_scale(mild)


def test_schedule_degrees_snapped_and_bounded():
    degs = program.schedule_degrees(15)
    assert degs[0] == 1 and all(d % 2 == 1 for d in degs)
    assert degs == tuple(sorted(set(degs)))
    assert max(degs) <= 15
    assert len(program.schedule_degrees(101)) <= 8  # planner grid size


def test_contraction_forecasts():
    rate = program.contraction_rate(0.4, 0.1, 20)
    assert rate is not None and 0 < rate < 1
    assert program.predicted_residual(0.1, rate, 20) == pytest.approx(
        0.1 * (0.1 / 0.4))
    n = program.predicted_steps_to_tol(0.1, rate, 1e-3)
    assert 0 < n < 10_000
    assert program.predicted_steps_to_tol(1e-4, rate, 1e-3) == 0
    # degenerate observations carry no signal
    assert program.contraction_rate(0.1, 0.4, 20) is None  # not decaying
    assert program.contraction_rate(float("inf"), 0.1, 20) is None
    assert program.predicted_steps_to_tol(0.1, None, 1e-3) >= 1 << 30


# ---------------------------------------------------------------------------
# schedule plumbing: compile-cache key space (satellite: invariant test)
# ---------------------------------------------------------------------------

SVC = ServiceConfig(k=4, num_clusters=3, degree=7, steps_per_tick=10,
                    lr=0.3, tol=5e-3, dilation_strength=6.0)


def test_per_session_schedules_do_not_grow_compile_cache():
    """Sessions with DIFFERENT per-session lr, dilation scale, and rho
    share one compiled program: the compile-cache key space is exactly
    (class, degree, layout) x pow2 occupancy x pow2 multiplier — the
    PR 4 logarithmic guarantee, now with the adaptive layer on top."""
    svc = StreamingService(SVC)
    for i in range(5):
        # different weights/densities -> different probed rho, scale, lr
        g = _rand_graph(30 + i, 48, 140 + 17 * i)
        svc.add_graph(f"s{i}", g, num_clusters=3, edge_capacity=512)
    scales = {round(s.plan.scale, 6) for s in svc._sessions.values()}
    assert len(scales) > 1  # genuinely distinct traced inputs
    svc.tick()
    svc.tick()
    group_keys = {key for key, _ in svc._compiled}
    # every session landed in a (class, degree) group whose degree is on
    # the snapped planner grid
    allowed = set(program.schedule_degrees(SVC.degree))
    assert {key[1] for key in group_keys} <= allowed
    # two plain ticks at constant occupancy: one program per group
    assert svc.compile_count == len(group_keys)
    svc.run_until_converged(max_ticks=200)
    # the scheduler's multipliers are traced chunk counts: however many
    # multiplied ticks ran, the compiled set only grew along the pow2
    # occupancy ladder (<= 1 + log2(max occupancy) buckets per group)
    occ_budget = 1 + int(math.log2(8))  # 5 sessions pad to <= 8
    assert svc.compile_count <= len(group_keys) * occ_budget
    for key, occ in svc._compiled:
        assert occ == 1 << (occ.bit_length() - 1)


# ---------------------------------------------------------------------------
# converged sessions cost zero device work (satellite: small fix)
# ---------------------------------------------------------------------------

def test_converged_sessions_cost_zero_device_work():
    svc = StreamingService(SVC)
    for i in range(2):
        g, _ = graphs.sbm_graph(50, 3, p_in=0.4, p_out=0.02, seed=i)
        svc.add_graph(f"g{i}", g, num_clusters=3, edge_capacity=512)
    svc.tick()
    base_work = svc.device_work
    base_inv = svc.tick_invocations
    assert base_work >= 2 * SVC.steps_per_tick  # both sessions ticked
    # one session converges -> its slot leaves the group entirely
    svc._sessions["g0"].converged = True
    svc.tick()
    delta = svc.device_work - base_work
    # occupancy 1, multiplier 1 (g1's first tick left no decay-rate
    # forecast yet): exactly one session-slot of steps, not two
    assert delta == svc.cfg.steps_per_tick
    # all converged -> a tick runs NO programs at all
    svc._sessions["g1"].converged = True
    work, inv = svc.device_work, svc.tick_invocations
    assert svc.tick() == {}
    assert svc.device_work == work
    assert svc.tick_invocations == inv


# ---------------------------------------------------------------------------
# panel caching across evict / re-admit (satellite)
# ---------------------------------------------------------------------------

def test_evicted_panel_warm_starts_readmission():
    svc = StreamingService(SVC)
    g, _ = graphs.sbm_graph(60, 3, p_in=0.4, p_out=0.02, seed=7)
    svc.add_graph("t", g, num_clusters=3, edge_capacity=1024)
    svc.run_until_converged(max_ticks=100)
    cold_ticks = svc.session_info("t")["ticks"]
    assert cold_ticks >= 2  # the comparison below is meaningful
    summary = svc.evict("t")
    panel = summary["panel"]
    assert panel.shape == (g.num_nodes, SVC.k)
    assert "t" not in svc._sessions
    # re-admit the tenant with its cached panel: reconverges in a
    # fraction of the cold admission's ticks
    svc.add_graph("t", g, num_clusters=3, edge_capacity=1024,
                  resume_panel=panel)
    svc.run_until_converged(max_ticks=100)
    info = svc.session_info("t")
    assert info["converged"]
    assert info["ticks"] < cold_ticks
    # node-padding invariant survives the resume path
    v = np.asarray(svc._sessions["t"].v)
    np.testing.assert_array_equal(v[g.num_nodes:], 0.0)


def test_resume_panel_shape_validated():
    svc = StreamingService(SVC)
    g, _ = graphs.sbm_graph(40, 2, p_in=0.4, p_out=0.02, seed=0)
    with pytest.raises(ValueError, match="resume_panel"):
        svc.add_graph("bad", g, num_clusters=3,
                      resume_panel=np.zeros((10, SVC.k), np.float32))


# ---------------------------------------------------------------------------
# residual-decay tick scheduler vs round-robin
# ---------------------------------------------------------------------------

def _mixed_fleet(svc: StreamingService):
    for i in range(2):  # fast-converging: well separated communities
        g, _ = graphs.sbm_graph(60, 3, p_in=0.45, p_out=0.01, seed=i)
        svc.add_graph(f"fast{i}", g, num_clusters=3, edge_capacity=1024)
    for i in range(2):  # slow-converging: weak structure
        g, _ = graphs.sbm_graph(60, 3, p_in=0.16, p_out=0.06, seed=10 + i)
        svc.add_graph(f"slow{i}", g, num_clusters=3, edge_capacity=1024)


def test_residual_decay_scheduler_beats_round_robin():
    cfg = dataclasses.replace(SVC, steps_per_tick=10, tol=2e-3)
    rr = StreamingService(
        dataclasses.replace(cfg, tick_schedule="round_robin"))
    sched = StreamingService(cfg)
    _mixed_fleet(rr)
    _mixed_fleet(sched)
    rr.run_until_converged(max_ticks=400)
    sched.run_until_converged(max_ticks=400)
    assert rr.all_converged and sched.all_converged
    # fewer compiled-program invocations (and their residual evals /
    # host syncs) to fleet convergence on the mixed-rate fleet
    assert sched.tick_invocations < rr.tick_invocations
    # no per-tenant quality regression: everyone at tolerance
    for sid in ("fast0", "fast1", "slow0", "slow1"):
        assert sched.session_info(sid)["residual"] <= cfg.tol
    # the scheduler actually stretched ticks — through TRACED chunk
    # counts, so its compiled-program set is no larger than round-robin's
    assert sched.multiplied_ticks > 0
    assert rr.multiplied_ticks == 0
    assert sched.compile_count <= rr.compile_count + 1


# ---------------------------------------------------------------------------
# bench --check (satellite: CI tooling)
# ---------------------------------------------------------------------------

def test_bench_regressions_diff():
    import os
    import sys

    # benchmarks/ is a repo-root package (normally imported via
    # `python -m benchmarks.run` from the root); make the test
    # cwd-independent
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.common import bench_regressions

    old = {"rows": [{"name": "a", "us_per_call": 100.0, "derived": ""},
                    {"name": "b", "us_per_call": 50.0, "derived": ""}],
           "iter_speedup_warm_vs_cold": 7.5}
    ok = {"rows": [{"name": "a", "us_per_call": 110.0, "derived": ""},
                   {"name": "b", "us_per_call": 60.0, "derived": ""},
                   {"name": "new_row", "us_per_call": 9e9, "derived": ""}],
          "iter_speedup_warm_vs_cold": 7.0}
    assert bench_regressions(old, ok) == []
    bad = {"rows": [{"name": "a", "us_per_call": 200.0, "derived": ""}],
           "iter_speedup_warm_vs_cold": 2.0}
    msgs = bench_regressions(old, bad)
    assert len(msgs) == 2
    assert any("a:" in m for m in msgs)
    assert any("iter_speedup" in m for m in msgs)
