"""Related-work baselines (paper App. B): Bethe Hessian, shift-and-invert,
Lanczos reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, graphs, laplacian_dense, metrics
from repro.core.kmeans import cluster_agreement


def test_bethe_hessian_recovers_sbm_communities():
    g, truth = graphs.sbm_graph(180, 3, p_in=0.25, p_out=0.01, seed=0)
    labels, info = baselines.bethe_hessian_cluster(g, 3)
    acc = float(cluster_agreement(labels, jnp.asarray(truth), 3))
    assert acc > 0.9, acc
    assert info["negative_eigs"] >= 3  # one per community (Saade et al.)


def test_cg_solves_spd_system():
    key = jax.random.PRNGKey(0)
    n = 40
    a = jax.random.normal(key, (n, n))
    a = a @ a.T + n * jnp.eye(n)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, 3))
    x = baselines.cg_solve(lambda v: a @ v, b, iters=80)
    np.testing.assert_allclose(a @ x, b, rtol=1e-3, atol=1e-3)


def test_shift_invert_operator_finds_bottom_eigvec():
    from repro.core import SolverConfig, run_solver
    g, _ = graphs.ring_of_cliques(3, 6)
    L = laplacian_dense(g)
    k = 3
    _, v_star = metrics.ground_truth_bottom_k(L, k)
    op = baselines.shift_invert_operator(lambda v: L @ v, shift=0.05,
                                         cg_iters=40)
    cfg = SolverConfig(method="oja", lr=0.5, steps=200, eval_every=25, k=k)
    _, tr = run_solver(op, g.num_nodes, cfg, v_star=v_star)
    assert float(tr.subspace_error[-1]) < 1e-2


def test_lanczos_matches_eigh():
    g, _ = graphs.clique_graph(120, 3, seed=1)
    L = laplacian_dense(g)
    lam_ref = jnp.linalg.eigvalsh(L)[:4]
    lam, vecs = baselines.lanczos_bottom_k(lambda v: L @ v, g.num_nodes, 4,
                                           iters=110)
    np.testing.assert_allclose(lam, lam_ref, rtol=1e-3, atol=1e-3)
    # eigenvector residuals
    res = jnp.linalg.norm(L @ vecs - vecs * lam[None, :], axis=0)
    assert float(jnp.max(res)) < 1e-2


def test_lanczos_as_ground_truth_for_sped():
    """Large-graph protocol: Lanczos oracle replaces dense eigh."""
    from repro.core import (SolverConfig, limit_neg_exp, run_solver,
                            spectral_radius_upper_bound)
    from repro.core import operators
    g, _ = graphs.clique_graph(300, 3, seed=2)
    L = laplacian_dense(g)
    k = 3
    _, v_star = baselines.lanczos_bottom_k(lambda v: L @ v, g.num_nodes, k)
    s = limit_neg_exp(151)
    op = operators.series_operator(s, operators.dense_matvec(L))
    cfg = SolverConfig(method="mu_eg", lr=0.4, steps=500, eval_every=100,
                       k=k)
    _, tr = run_solver(op, g.num_nodes, cfg, v_star=v_star)
    assert float(tr.subspace_error[-1]) < 0.02
