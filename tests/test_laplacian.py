"""Laplacian / incidence identities (paper Sec. 2, Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (see pyproject.toml [test] extra)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    EdgeList, adjacency_dense, build_edge_incidence, degrees,
    edge_inner_product, incidence_matrix, laplacian_dense,
    laplacian_matvec, make_edge_list, minibatch_laplacian_matvec,
    normalized_laplacian_dense, spectral_radius_upper_bound,
)
from repro.core import graphs


def random_graph(seed, n=12, p=0.4):
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, k=1)
    mask = rng.random(len(iu[0])) < p
    edges = np.stack([iu[0][mask], iu[1][mask]], axis=1)
    if len(edges) == 0:
        edges = np.array([[0, 1]])
    w = rng.uniform(0.1, 2.0, size=len(edges))
    return make_edge_list(edges, n, weights=w)


def test_laplacian_equals_incidence_gram():
    g, _ = graphs.ring_of_cliques(3, 5)
    X = incidence_matrix(g)
    L = laplacian_dense(g)
    np.testing.assert_allclose(L, X.T @ X, atol=1e-5)


def test_weighted_laplacian_equals_xtwx():
    g = random_graph(0)
    X = incidence_matrix(g)
    L = laplacian_dense(g)
    np.testing.assert_allclose(L, X.T @ (g.weight[:, None] * X), atol=1e-5)


def test_ones_is_nullvector():
    g, _ = graphs.ring_of_cliques(4, 4)
    L = laplacian_dense(g)
    np.testing.assert_allclose(L @ jnp.ones(g.num_nodes), 0.0, atol=1e-5)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_matvec_matches_dense(seed):
    g = random_graph(seed)
    L = laplacian_dense(g)
    v = np.random.default_rng(seed + 1).normal(size=(g.num_nodes, 3)).astype(np.float32)
    np.testing.assert_allclose(laplacian_matvec(g, jnp.asarray(v)), L @ v,
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_laplacian_psd_and_symmetric(seed):
    g = random_graph(seed)
    L = np.asarray(laplacian_dense(g))
    np.testing.assert_allclose(L, L.T, atol=1e-6)
    lam = np.linalg.eigvalsh(L)
    assert lam.min() > -1e-4
    # spectral radius upper bound (paper Sec. 5.4): lam_max <= 2 deg*
    assert lam.max() <= float(spectral_radius_upper_bound(g)) + 1e-4


def test_minibatch_matvec_1d_and_2d_agree():
    """Regression: the 1-D and (N, 1) forms weight edges identically
    (the old jnp.atleast_2d(diff.T).T contortion is gone) and the
    full-edge-set minibatch equals the exact matvec."""
    g = random_graph(5)
    rng = np.random.default_rng(9)
    v = jnp.asarray(rng.normal(size=(g.num_nodes,)), jnp.float32)
    sel = jnp.asarray(rng.integers(0, g.num_edges, 16), jnp.int32)
    out1 = minibatch_laplacian_matvec(
        g.src[sel], g.dst[sel], g.weight[sel], v, g.num_edges)
    out2 = minibatch_laplacian_matvec(
        g.src[sel], g.dst[sel], g.weight[sel], v[:, None], g.num_edges)
    assert out1.shape == (g.num_nodes,)
    assert out2.shape == (g.num_nodes, 1)
    np.testing.assert_allclose(out1, out2[:, 0], rtol=1e-6, atol=1e-6)
    # scale E_total/B == 1 on the full edge set => exact L @ v
    full = minibatch_laplacian_matvec(
        g.src, g.dst, g.weight, v, g.num_edges)
    np.testing.assert_allclose(full, laplacian_matvec(g, v),
                               rtol=1e-5, atol=1e-5)


def test_minibatch_matvec_unbiased():
    g, _ = graphs.ring_of_cliques(3, 5)
    L = laplacian_dense(g)
    v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 2))
    exact = L @ v
    total = jnp.zeros_like(v)
    trials = 600
    for t in range(trials):
        key = jax.random.PRNGKey(t + 1)
        sel = jax.random.randint(key, (8,), 0, g.num_edges)
        total = total + minibatch_laplacian_matvec(
            g.src[sel], g.dst[sel], g.weight[sel], v, g.num_edges)
    err = jnp.linalg.norm(total / trials - exact) / jnp.linalg.norm(exact)
    assert float(err) < 0.15  # ~1/sqrt(600*8/E) Monte-Carlo tolerance


# --- Table 1: inner products of edge vectors ------------------------------

def test_table1_disconnected():
    assert float(edge_inner_product(0, 1, 2, 3)) == 0.0


def test_table1_serial():
    # i -> j -> l with i<j<l: edges (i,j),(j,l) share j at opposite signs
    assert float(edge_inner_product(0, 1, 1, 2)) == -1.0


def test_table1_converging():
    # i -> j <- l: edges (i,j),(l,j) share j at same sign (-1,-1)
    assert float(edge_inner_product(0, 2, 1, 2)) == 1.0


def test_table1_diverging():
    # i <- j -> l: edges (j,i)... canonical (min,max): (0,1),(0,2) share 0
    assert float(edge_inner_product(0, 1, 0, 2)) == 1.0


def test_table1_repeated():
    assert float(edge_inner_product(3, 7, 3, 7)) == 2.0


def test_incidence_graph_matches_inner_products():
    g, _ = graphs.ring_of_cliques(3, 4)
    inc = build_edge_incidence(g)
    X = np.asarray(incidence_matrix(g))
    gram = X @ X.T  # (E, E) inner products
    E = g.num_edges
    for e in range(E):
        d = int(inc.deg[e])
        nbrs = np.asarray(inc.nbrs[e, :d])
        # neighbours = exactly the nonzero entries of gram row e
        expected = set(np.nonzero(gram[e])[0].tolist())
        assert set(nbrs.tolist()) == expected
        np.testing.assert_allclose(np.asarray(inc.ip[e, :d]), gram[e, nbrs])
        # degree bound of paper Sec 4.3: deg_inc <= 2 deg* - 1... (+1 self)
        assert d <= inc.deg_star_inc + 1


def test_normalized_laplacian_spectrum_bounded():
    g, _ = graphs.ring_of_cliques(4, 5)
    Ln = np.asarray(normalized_laplacian_dense(g))
    lam = np.linalg.eigvalsh(Ln)
    assert lam.min() > -1e-5 and lam.max() < 2.0 + 1e-5


def test_three_room_mdp_structure():
    g, labels = graphs.three_room_mdp(s=1, h=10)
    h, w = 11, 31
    assert g.num_nodes == h * w
    assert set(np.unique(np.asarray(labels))) == {0, 1, 2}
    # connected: nullspace of L is 1-dim
    L = np.asarray(laplacian_dense(g))
    lam = np.linalg.eigvalsh(L)
    assert lam[0] < 1e-5 and lam[1] > 1e-6  # connected => single zero eig


def test_clique_graph_ground_truth_separation():
    g, labels = graphs.clique_graph(120, 3, seed=1)
    L = np.asarray(laplacian_dense(g))
    lam = np.linalg.eigvalsh(L)
    # 3 clusters => 3 eigenvalues << bulk (paper Sec. 2.1)
    assert lam[2] < 0.2 * lam[3]
