"""Solvers + the paper's headline claim: dilation accelerates convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SolverConfig, identity_series, laplacian_dense, limit_neg_exp,
    run_solver, steps_to_streak, with_lambda_star,
)
from repro.core import graphs, metrics, operators
from repro.core.solvers import init_state, mu_eg_step, oja_step


def test_oja_converges_on_psd_matrix():
    key = jax.random.PRNGKey(0)
    n, k = 24, 3
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    lam = jnp.concatenate([jnp.asarray([10.0, 8.0, 6.0]), jnp.linspace(1, 2, n - 3)])
    a = (q * lam[None, :]) @ q.T
    v_star = q[:, :3]
    cfg = SolverConfig(method="oja", lr=0.05, steps=800, eval_every=50, k=k)
    _, tr = run_solver(lambda v: a @ v, n, cfg, v_star=v_star)
    assert float(tr.subspace_error[-1]) < 1e-3
    assert int(tr.streak[-1]) == k


def test_mu_eg_converges_on_psd_matrix():
    key = jax.random.PRNGKey(1)
    n, k = 24, 3
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    lam = jnp.concatenate([jnp.asarray([10.0, 8.0, 6.0]), jnp.linspace(1, 2, n - 3)])
    a = (q * lam[None, :]) @ q.T
    v_star = q[:, :3]
    cfg = SolverConfig(method="mu_eg", lr=0.02, steps=1500, eval_every=50, k=k)
    _, tr = run_solver(lambda v: a @ v, n, cfg, v_star=v_star)
    assert float(tr.subspace_error[-1]) < 1e-3
    assert int(tr.streak[-1]) == k


def test_updates_preserve_unit_norm():
    key = jax.random.PRNGKey(2)
    n, k = 16, 4
    a = jax.random.normal(key, (n, n))
    a = a @ a.T
    st = init_state(key, n, k)
    for step_fn in (oja_step, mu_eg_step):
        s = st
        for _ in range(5):
            s = step_fn(s, a @ s.v, 0.01)
        norms = jnp.linalg.norm(s.v, axis=0)
        np.testing.assert_allclose(norms, 1.0, atol=1e-4)


@pytest.mark.parametrize("method", ["mu_eg", "oja"])
def test_dilation_accelerates_streak(method):
    """THE paper claim (Figs. 2-4): the limit series of -e^{-L} reaches a
    full eigenvector streak in ~an order of magnitude fewer steps than the
    identity transformation."""
    g, _ = graphs.clique_graph(200, 4, seed=0)
    L = laplacian_dense(g)
    k = 4
    _, v_star = metrics.ground_truth_bottom_k(L, k)
    rho_ub = float(2 * jnp.max(jnp.diag(L)))
    mv = operators.dense_matvec(L)

    ident = operators.series_operator(
        with_lambda_star(identity_series(), rho_ub * 1.01), mv)
    cfg_i = SolverConfig(method=method, lr=2e-2, steps=3000, eval_every=25, k=k)
    _, tr_i = run_solver(ident, g.num_nodes, cfg_i, v_star=v_star)
    steps_ident = steps_to_streak(tr_i, k)

    dilated = operators.series_operator(limit_neg_exp(251), mv)
    cfg_d = SolverConfig(method=method, lr=0.5, steps=3000, eval_every=25, k=k)
    _, tr_d = run_solver(dilated, g.num_nodes, cfg_d, v_star=v_star)
    steps_dil = steps_to_streak(tr_d, k)

    assert steps_dil > 0, "dilated solver never converged"
    assert steps_ident == -1 or steps_dil * 4 <= steps_ident, (
        f"dilation did not accelerate: {steps_dil} vs {steps_ident}")


def test_stochastic_minibatch_operator_converges():
    """Paper Sec. 3 stochastic model: minibatches of edges only."""
    g, _ = graphs.clique_graph(120, 3, seed=2)
    L = laplacian_dense(g)
    k = 3
    _, v_star = metrics.ground_truth_bottom_k(L, k)
    rho_ub = float(2 * jnp.max(jnp.diag(L)))
    s = limit_neg_exp(51, scale=6.0 / rho_ub)
    op = operators.minibatch_operator(g, s, batch_edges=512)
    cfg = SolverConfig(method="mu_eg", lr=0.1, steps=1200, eval_every=100, k=k)
    _, tr = run_solver(op, g.num_nodes, cfg, v_star=v_star, stochastic=True)
    assert float(tr.subspace_error[-1]) < 0.05


def test_exact_operator_matches_series_operator():
    g, _ = graphs.ring_of_cliques(3, 5)
    L = laplacian_dense(g)
    s = limit_neg_exp(51)
    v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 2))
    via_series = operators.series_operator(s, operators.dense_matvec(L))(v)
    via_eigh = operators.exact_operator(s, L)(v)
    np.testing.assert_allclose(via_series, via_eigh, atol=2e-3)
