"""Seed audit for the ``stochastic`` suite.

The ``stochastic`` marker's contract (pyproject.toml) is that every
such test is DETERMINISTIC run-to-run: the assertions rest on
concentration bounds, but the draws come from explicitly threaded PRNG
seeds, so a failure is a real regression and ``--stochastic-reruns``
(tests/conftest.py) reproduces it instead of flaking.  This audit
enforces the contract structurally: every stochastic-marked test
function must visibly thread an explicit seed — a ``PRNGKey(...)``,
``seed=``, ``default_rng(...)``, or ``fold_in(...)`` — in its own
source.  A test that draws entropy implicitly (time, global RNG state)
has no such token and fails here before it ever flakes in CI.
"""
import ast
import pathlib

import pytest

TESTS_DIR = pathlib.Path(__file__).parent

# Tokens that witness an explicit seed.  `seed=` covers graph builders
# and SolverConfig/ServiceConfig (all of which require the caller to
# pick the seed); the jax and numpy constructors cover direct draws.
SEED_TOKENS = ("PRNGKey(", "seed=", "default_rng(", "fold_in(")


def _is_stochastic_marker(node: ast.expr) -> bool:
    """True for ``pytest.mark.stochastic`` (bare or called) — attribute
    match, not substring, so e.g. a parametrize id mentioning the word
    doesn't count."""
    target = node.func if isinstance(node, ast.Call) else node
    return isinstance(target, ast.Attribute) and target.attr == "stochastic"


def _module_marked_stochastic(tree: ast.Module) -> bool:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == "pytestmark"
                   for t in node.targets):
                values = (node.value.elts
                          if isinstance(node.value, (ast.List, ast.Tuple))
                          else [node.value])
                if any(_is_stochastic_marker(v) for v in values):
                    return True
    return False


def _stochastic_test_functions():
    """(file, name, source) for every stochastic-marked test function."""
    found = []
    for path in sorted(TESTS_DIR.glob("test_*.py")):
        text = path.read_text()
        tree = ast.parse(text)
        module_marked = _module_marked_stochastic(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            if not node.name.startswith("test"):
                continue
            marked = module_marked or any(
                _is_stochastic_marker(dec) for dec in node.decorator_list)
            if marked:
                found.append((path.name, node.name,
                              ast.get_source_segment(text, node) or ""))
    return found


def test_stochastic_suite_is_nonempty():
    """The audit audits something: the spectral probing suite alone
    carries several stochastic-marked tests."""
    assert len(_stochastic_test_functions()) >= 8


_CASES = _stochastic_test_functions()  # one scan for argvalues AND ids


@pytest.mark.parametrize(
    "fname,tname,source", _CASES,
    ids=[f"{f}::{t}" for f, t, _ in _CASES])
def test_stochastic_test_threads_explicit_seed(fname, tname, source):
    assert any(tok in source for tok in SEED_TOKENS), (
        f"{fname}::{tname} is marked `stochastic` but no explicit PRNG "
        f"seed token {SEED_TOKENS} appears in its source; thread a "
        "fixed seed (see the stochastic marker contract in "
        "pyproject.toml and README's Verify section)")
