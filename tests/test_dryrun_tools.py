"""Unit tests for the dry-run's HLO analysis tooling (parser correctness
matters: the roofline's collective term comes from it)."""
import numpy as np

from repro.launch.hlo_analysis import (_loop_multipliers, _shape_bytes,
                                        _split_computations,
                                        collective_bytes,
                                        cpu_dot_upcast_bytes)

HLO = """\
HloModule jit_step, entry_computation_layout={()->()}

%wrapped_convert_computation (param_0: bf16[64,512,512]) -> f32[64,512,512] {
  %param_0 = bf16[64,512,512]{2,1,0} parameter(0)
  ROOT %convert.1 = f32[64,512,512]{2,1,0} convert(%param_0)
}

%region_body (param: (s32[], f32[16,512])) -> (s32[], f32[16,512]) {
  %param = (s32[], f32[16,512]{1,0}) parameter(0)
  %ar = f32[16,512]{1,0} all-reduce(%gte), replica_groups={}
  %inner = (s32[], f32[8,8]{1,0}) while(%t2), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %tup = (s32[], f32[16,512]{1,0}) tuple(%iv, %ar)
}

%inner_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %ag = f32[8,8]{1,0} all-gather(%x), dimensions={0}
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%i, %ag)
}

%inner_cond (p2: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(4)
  ROOT %cmp = pred[] compare(%i2, %c), direction=LT
}

%region_cond (paramc: (s32[], f32[16,512])) -> pred[] {
  %c10 = s32[] constant(10)
  ROOT %cmp2 = pred[] compare(%ivc, %c10), direction=LT
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %w = (s32[], f32[16,512]{1,0}) while(%t0), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"10"}}
  %top = f32[4,4]{1,0} all-reduce(%a), replica_groups={}
  ROOT %r = f32[4,4]{1,0} copy(%top)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,512]") == 16 * 512 * 4
    assert _shape_bytes("bf16[8,8]") == 128
    assert _shape_bytes("(f32[2,2], s8[4])") == 20


def test_split_computations():
    comps = _split_computations(HLO)
    assert {"wrapped_convert_computation", "region_body", "inner_body",
            "inner_cond", "region_cond", "main"} <= set(comps)


def test_loop_multipliers_nested():
    comps = _split_computations(HLO)
    m = _loop_multipliers(comps)
    assert m["main"] == 1
    assert m["region_body"] == 10  # known_trip_count
    assert m["inner_body"] == 40  # nested: 10 * 4


def test_collective_bytes_loop_aware():
    got = collective_bytes(HLO)
    # top-level AR: 4*4*4 = 64 B; loop AR: 16*512*4 * 10; nested AG:
    # 8*8*4 * 40
    assert got["bytes"]["all-reduce"] == 64 + 16 * 512 * 4 * 10
    assert got["bytes"]["all-gather"] == 8 * 8 * 4 * 40
    assert got["count"]["all-reduce"] == 11


def test_cpu_dot_upcast_bytes():
    assert cpu_dot_upcast_bytes(HLO) == 64 * 512 * 512 * 4
