"""Model-layer unit tests: SSD vs sequential oracle, chunked attention vs
dense, MoE dispatch invariants, RoPE/norm properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (see pyproject.toml [test] extra)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch, smoke_config
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm


def test_ssd_chunked_matches_sequential_oracle():
    """The chunked SSD algorithm == step-by-step recurrence (f32)."""
    cfg = smoke_config(get_arch("mamba2-2.7b"))
    key = jax.random.PRNGKey(0)
    p = ssm_mod.init_ssm(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (2, 12, cfg.d_model), jnp.float32) * 0.3
    got = ssm_mod.ssm_train(p, cfg, x)
    want = ssm_mod.ssm_reference_scan(p, cfg, x)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
def test_ssd_chunk_size_invariance(chunk):
    """Output must not depend on the chunking (algebraic identity)."""
    import dataclasses
    cfg = smoke_config(get_arch("mamba2-2.7b"))
    key = jax.random.PRNGKey(1)
    p = ssm_mod.init_ssm(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 2),
                          (1, 16, cfg.d_model), jnp.float32) * 0.3
    cfg1 = dataclasses.replace(cfg, ssm_chunk=chunk)
    cfg2 = dataclasses.replace(cfg, ssm_chunk=16)
    np.testing.assert_allclose(
        ssm_mod.ssm_train(p, cfg1, x), ssm_mod.ssm_train(p, cfg2, x),
        rtol=2e-2, atol=2e-2)


def test_chunked_attention_matches_dense():
    key = jax.random.PRNGKey(2)
    b, s, h, dh = 2, 64, 4, 16
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    dense = attn_mod._dense_attention(q, k, v, causal=True, q_offset=0)
    for chunk in (8, 16, 64):
        chunked = attn_mod._chunked_attention(q, k, v, causal=True,
                                              q_offset=0, chunk=chunk)
        np.testing.assert_allclose(chunked, dense, rtol=1e-4, atol=1e-4)


def test_chunked_attention_noncausal():
    key = jax.random.PRNGKey(3)
    b, s, h, dh = 1, 40, 2, 8
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    dense = attn_mod._dense_attention(q, k, v, causal=False, q_offset=0)
    chunked = attn_mod._chunked_attention(q, k, v, causal=False,
                                          q_offset=0, chunk=16)
    np.testing.assert_allclose(chunked, dense, rtol=1e-4, atol=1e-4)


def test_gqa_decode_matches_train_lastpos():
    """Decode at position s == train attention's last row."""
    cfg = smoke_config(get_arch("qwen3-4b"))
    key = jax.random.PRNGKey(4)
    p = attn_mod.init_attention(key, cfg)
    b, s = 1, 10
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, s, cfg.d_model), jnp.float32) * 0.3
    out_train, _ = attn_mod.gqa_train(p, cfg, x)
    cache = attn_mod.init_kv_cache(cfg, b, s, cfg.num_kv_heads, cfg.head_dim)
    for t in range(s):
        out_dec, cache = attn_mod.gqa_decode(p, cfg, x[:, t: t + 1], cache)
    np.testing.assert_allclose(out_train[:, -1:], out_dec, rtol=3e-2,
                               atol=3e-2)


def test_int8_kv_cache_roundtrip_quality():
    import dataclasses
    cfg = dataclasses.replace(smoke_config(get_arch("qwen1.5-32b")),
                              kv_cache_dtype="int8")
    key = jax.random.PRNGKey(5)
    k_new = jax.random.normal(key, (2, 6, cfg.num_kv_heads, cfg.head_dim))
    v_new = jax.random.normal(jax.random.fold_in(key, 1), k_new.shape)
    cache = attn_mod.init_kv_cache(cfg, 2, 8, cfg.num_kv_heads, cfg.head_dim)
    cache = attn_mod.cache_update(cache, k_new, v_new, 0)
    k, v = attn_mod.cache_kv(cache, jnp.float32)
    # int8 with per-(pos, head) scales: ~1% error
    err = float(jnp.max(jnp.abs(k[:, :6] - k_new)) / jnp.max(jnp.abs(k_new)))
    assert err < 0.02, err


# --- MoE --------------------------------------------------------------------

def test_moe_outputs_finite_and_gates_normalized():
    cfg = smoke_config(get_arch("granite-moe-1b-a400m"))
    p = moe_mod.init_moe(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, cfg.d_model),
                          jnp.float32) * 0.3
    out, aux = moe_mod.moe_ffn(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0  # load-balance loss is positive


def test_moe_capacity_drops_when_overloaded():
    """Force every token to one expert: most must be dropped, output
    stays finite (capacity semantics)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config(get_arch("granite-moe-1b-a400m")),
                              capacity_factor=0.05)
    p = moe_mod.init_moe(jax.random.PRNGKey(8), cfg)
    # bias router hard toward expert 0
    p["router"] = p["router"].at[:, 0].set(100.0)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 64, cfg.d_model),
                          jnp.float32) * 0.3
    out, aux = moe_mod.moe_ffn(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_matches_dense_reference_when_capacity_ample():
    """With capacity >> tokens, sort-based dispatch == direct per-token
    expert evaluation."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config(get_arch("granite-moe-1b-a400m")),
                              capacity_factor=8.0)
    p = moe_mod.init_moe(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 6, cfg.d_model),
                          jnp.float32) * 0.3
    got, _ = moe_mod.moe_ffn(p, cfg, x)

    # dense reference
    toks = x.reshape(-1, cfg.d_model)
    logits = toks @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, cfg.moe_top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(toks)
    for t in range(toks.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe_top_k):
            e = int(ei[t, j])
            h = jax.nn.silu(toks[t] @ p["w_gate"][e]) * (toks[t] @ p["w_up"][e])
            acc = acc + gv[t, j] * (h @ p["w_down"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(got.reshape(-1, cfg.d_model), want,
                               rtol=2e-2, atol=2e-2)


# --- layer properties -------------------------------------------------------

@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, 6, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(6), (1, 6))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q, i), rope(k, j)> depends only on i - j."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i), 10000.0)
        kj = apply_rope(k, jnp.full((1, 1), j), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3


def test_rmsnorm_scale_invariance():
    p = init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    np.testing.assert_allclose(rmsnorm(p, x), rmsnorm(p, 10.0 * x),
                               rtol=1e-4, atol=1e-5)
