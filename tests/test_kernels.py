"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps + hypothesis property tests per kernel, as required:
every kernel asserts allclose against its ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (see pyproject.toml [test] extra)")
from hypothesis import given, settings, strategies as st

from repro.kernels.edge_spmm import ops as es_ops, ref as es_ref
from repro.kernels.eg_update import ops as eg_ops, ref as eg_ref
from repro.kernels.laplacian_poly import ops as lp_ops, ref as lp_ref

pytestmark = pytest.mark.pallas

I = dict(interpret=True)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# --- laplacian_poly --------------------------------------------------------

@pytest.mark.parametrize("n", [128, 256, 300, 512])
@pytest.mark.parametrize("k", [1, 4, 8])
def test_poly_step_shapes(n, k):
    l_mat = rand(0, (n, n))
    l_mat = l_mat + l_mat.T
    u = rand(1, (n, k))
    got = lp_ops.poly_step(l_mat, u, 0.02, **I)
    want = lp_ref.poly_step(l_mat, u, 0.02)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_poly_step_dtypes(dtype):
    n, k = 256, 4
    l_mat = rand(2, (n, n), dtype)
    u = rand(3, (n, k), dtype)
    got = lp_ops.poly_step(l_mat, u, 0.1, **I)
    want = lp_ref.poly_step(l_mat.astype(jnp.float32), u.astype(jnp.float32), 0.1)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_limit_series_apply_matches_series_module():
    """Kernel path == core.series recurrence == eigh oracle."""
    from repro.core import limit_neg_exp
    n, k, deg = 256, 3, 11
    l_mat = rand(4, (n, n))
    l_mat = (l_mat + l_mat.T) / 20
    v = rand(5, (n, k))
    got = lp_ops.limit_series_apply(l_mat, v, degree=deg, **I)
    want = limit_neg_exp(deg).apply(lambda u: l_mat @ u, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 500))
@settings(max_examples=8, deadline=None)
def test_poly_step_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 64)) * 8
    k = int(rng.integers(1, 6))
    c = float(rng.uniform(-0.5, 0.5))
    l_mat = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    got = lp_ops.poly_step(l_mat, u, c, block=128, **I)
    want = lp_ref.poly_step(l_mat, u, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# --- edge_spmm -------------------------------------------------------------

@pytest.mark.parametrize("e", [64, 128, 200, 512])
@pytest.mark.parametrize("n,k", [(50, 2), (256, 8), (300, 5)])
def test_edge_spmm_shapes(e, n, k):
    key = jax.random.PRNGKey(e * 7 + n)
    src = jax.random.randint(key, (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(key, 1), (e,), 0, n)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (e,))
    v = rand(6, (n, k))
    got = es_ops.edge_spmm(src, dst, w, v, **I)
    want = es_ref.edge_spmm(src, dst, w, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_edge_spmm_equals_laplacian_on_full_edge_set():
    """Full-batch edge_spmm == dense Laplacian matvec (paper L = X^T W X)."""
    from repro.core import graphs, laplacian_dense
    g, _ = graphs.ring_of_cliques(3, 6)
    v = rand(7, (g.num_nodes, 4))
    got = es_ops.edge_spmm(g.src, g.dst, g.weight, v, **I)
    want = laplacian_dense(g) @ v
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 500))
@settings(max_examples=8, deadline=None)
def test_edge_spmm_property(seed):
    rng = np.random.default_rng(seed)
    e = int(rng.integers(1, 300))
    n = int(rng.integers(4, 200))
    k = int(rng.integers(1, 9))
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 2, e), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    got = es_ops.edge_spmm(src, dst, w, v, **I)
    want = es_ref.edge_spmm(src, dst, w, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_edge_spmm_affine_epilogue():
    """alpha * L V + beta * V fused into the one-hot kernel epilogue."""
    rng = np.random.default_rng(3)
    e, n, k = 200, 120, 4
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 2, e), jnp.float32)
    v = rand(20, (n, k))
    got = es_ops.edge_spmm(src, dst, w, v, alpha=-0.3, beta=1.0, **I)
    want = es_ref.edge_spmm_affine(src, dst, w, v, -0.3, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 500))
@settings(max_examples=8, deadline=None)
def test_edge_spmm_node_blocked_property(seed):
    """build_node_blocking + blocked kernel == scatter-add oracle on
    random (unaligned) graphs and block sizes."""
    rng = np.random.default_rng(seed)
    e = int(rng.integers(1, 300))
    n = int(rng.integers(8, 200))
    k = int(rng.integers(1, 9))
    block_n = int(rng.choice([8, 16, 32, 64]))
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 2, e), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    got = es_ops.edge_spmm_blocked(nb, v, **I)
    # self-loops (src == dst) cancel in both paths: deg adds 2w, the two
    # half-edges subtract w each
    want = es_ref.edge_spmm(src, dst, w, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# --- node-blocking layout properties (single-device + per-shard) ----------

def _rand_blocking_case(seed: int):
    rng = np.random.default_rng(seed)
    e = int(rng.integers(1, 300))
    n = int(rng.integers(8, 200))
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    proper = src != dst  # self-loops excluded: a half-edge (u, u, w)
    src, dst = src[proper], dst[proper]  # cancels against deg in L v
    # DISTINCT weights so the half-edge multiset comparison is exact,
    # with some zero (capacity-padding) slots mixed in
    w = (np.arange(1, len(src) + 1, dtype=np.float32)
         * rng.uniform(0.5, 1.5)).astype(np.float32)
    w[rng.uniform(size=len(src)) < 0.2] = 0.0
    block_n = int(rng.choice([8, 16, 32, 64]))
    return src, dst, w, n, block_n


def _half_edge_multiset(src, dst, w):
    """Expected live half-edges {(u, o, w)}: two per live edge."""
    live = w != 0.0
    s, d, ww = src[live], dst[live], w[live]
    return sorted(zip(np.concatenate([s, d]).tolist(),
                      np.concatenate([d, s]).tolist(),
                      np.concatenate([ww, ww]).tolist()))


def _blocking_half_edges(nb: es_ops.NodeBlocking):
    """Live half-edges a blocking actually materialized, globalized.

    Walks the CSR chunk layout: chunk c belongs to block
    ``chunk_block[c]``, so a destination's global row id is
    ``chunk_block[c] * block_n + u_local``."""
    cb = np.asarray(nb.chunk_block)[: nb.num_chunks]
    ul = np.asarray(nb.u_local).reshape(nb.num_chunks, nb.block_e)
    ot = np.asarray(nb.other).reshape(nb.num_chunks, nb.block_e)
    wt = np.asarray(nb.weight).reshape(nb.num_chunks, nb.block_e)
    out = []
    for c in range(nb.num_chunks):
        live = wt[c] != 0.0
        out.extend(zip((ul[c, live] + int(cb[c]) * nb.block_n).tolist(),
                       ot[c, live].tolist(), wt[c, live].tolist()))
    return sorted(out)


def _check_blocking_covers_each_half_edge_once(seed: int):
    src, dst, w, n, block_n = _rand_blocking_case(seed)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    assert _blocking_half_edges(nb) == _half_edge_multiset(src, dst, w)
    # degrees match the live edges too
    deg = np.zeros(nb.padded_nodes, np.float32)
    np.add.at(deg, src, w)
    np.add.at(deg, dst, w)
    np.testing.assert_allclose(np.asarray(nb.deg), deg, rtol=1e-6)


def _check_sharded_blocking_covers_each_half_edge_once(seed: int):
    """Per-shard variant: shard s covers exactly ITS slice's half-edges
    (so the union covers everything once), per-shard degrees sum to the
    global degrees, and the chunk count is shared and pow2."""
    src, dst, w, n, block_n = _rand_blocking_case(seed)
    num_shards = int(np.random.default_rng(seed + 1).choice([2, 4, 8]))
    pad = (-len(src)) % num_shards
    src = np.concatenate([src, np.zeros(pad, src.dtype)])
    dst = np.concatenate([dst, np.zeros(pad, dst.dtype)])
    w = np.concatenate([w, np.zeros(pad, np.float32)])
    sb = es_ops.build_sharded_node_blocking(src, dst, w, n, num_shards,
                                            block_n=block_n)
    per = len(src) // num_shards
    assert sb.num_chunks == es_ops.next_pow2(sb.num_chunks)
    for s in range(num_shards):
        sl = slice(s * per, (s + 1) * per)
        assert (_blocking_half_edges(sb.shard(s))
                == _half_edge_multiset(src[sl], dst[sl], w[sl])), s
    deg = np.zeros(sb.padded_nodes, np.float32)
    np.add.at(deg, src, w)
    np.add.at(deg, dst, w)
    np.testing.assert_allclose(
        np.asarray(sb.deg).sum(axis=0), deg, rtol=1e-5, atol=1e-6)


def _check_blocking_node_permutation_invariance(seed: int):
    """Relabeling nodes commutes with the blocked matvec: permuting the
    graph and the panel permutes the result — the layout (which nodes
    share a block) is an implementation detail, not a semantics."""
    rng = np.random.default_rng(seed)
    src, dst, w, n, block_n = _rand_blocking_case(seed)
    k = int(rng.integers(1, 5))
    v = rng.normal(size=(n, k)).astype(np.float32)
    perm = rng.permutation(n)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    nb_p = es_ops.build_node_blocking(perm[src], perm[dst], w, n,
                                      block_n=block_n)
    out = np.asarray(es_ops.edge_spmm_blocked(nb, jnp.asarray(v), **I))
    v_p = np.empty_like(v)
    v_p[perm] = v
    out_p = np.asarray(es_ops.edge_spmm_blocked(nb_p, jnp.asarray(v_p), **I))
    np.testing.assert_allclose(out_p[perm], out, rtol=2e-4, atol=2e-4)


def _check_blocking_chunks_pow2_snapped(seed: int):
    src, dst, w, n, block_n = _rand_blocking_case(seed)
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    raw = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n,
                                     snap_chunks=False)
    assert nb.num_chunks == es_ops.next_pow2(raw.num_chunks)
    assert raw.num_chunks <= nb.num_chunks < 2 * max(raw.num_chunks, 1)


def _check_blocking_padding_inert(seed: int):
    """Capacity padding is invisible: the blocking of a padded buffer is
    bitwise the blocking of the live edges, and padding-only blocks
    (and shards) contribute exact zeros to the matvec."""
    rng = np.random.default_rng(seed)
    src, dst, w, n, block_n = _rand_blocking_case(seed)
    k = int(rng.integers(1, 5))
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    pad = int(rng.integers(1, 128))
    src_p = np.concatenate([src, np.zeros(pad, src.dtype)])
    dst_p = np.concatenate([dst, np.zeros(pad, dst.dtype)])
    w_p = np.concatenate([w, np.zeros(pad, np.float32)])
    nb = es_ops.build_node_blocking(src, dst, w, n, block_n=block_n)
    nb_p = es_ops.build_node_blocking(src_p, dst_p, w_p, n, block_n=block_n)
    for a, b in zip(nb[:4], nb_p[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert nb.num_chunks == nb_p.num_chunks
    # an all-padding shard is a zero operator (exact zeros, no NaN)
    sb = es_ops.build_sharded_node_blocking(
        np.zeros(16, np.int64), np.zeros(16, np.int64),
        np.zeros(16, np.float32), n, 4, block_n=block_n)
    out = np.asarray(es_ops.edge_spmm_blocked(sb.shard(0), v, **I))
    np.testing.assert_array_equal(out, 0.0)


@given(st.integers(1, 10_000))
@settings(max_examples=20, deadline=None)
def test_blocking_covers_each_half_edge_once(seed):
    _check_blocking_covers_each_half_edge_once(seed)


@given(st.integers(1, 10_000))
@settings(max_examples=10, deadline=None)
def test_sharded_blocking_covers_each_half_edge_once(seed):
    _check_sharded_blocking_covers_each_half_edge_once(seed)


@given(st.integers(1, 10_000))
@settings(max_examples=8, deadline=None)
def test_blocking_node_permutation_invariance(seed):
    _check_blocking_node_permutation_invariance(seed)


@given(st.integers(1, 10_000))
@settings(max_examples=20, deadline=None)
def test_blocking_chunks_pow2_snapped(seed):
    _check_blocking_chunks_pow2_snapped(seed)


@given(st.integers(1, 10_000))
@settings(max_examples=8, deadline=None)
def test_blocking_padding_inert(seed):
    _check_blocking_padding_inert(seed)


def test_limit_series_apply_edges_matches_dense():
    """Edge-list fused series == dense-kernel series == core.series."""
    from repro.core import graphs, laplacian_dense, limit_neg_exp

    g, _ = graphs.ring_of_cliques(3, 6)
    nb = es_ops.build_node_blocking(g.src, g.dst, g.weight, g.num_nodes,
                                    block_n=8)
    v = rand(21, (g.num_nodes, 3))
    got = lp_ops.limit_series_apply_edges(nb, v, degree=9, scale=0.5,
                                          interpret=True)
    want = limit_neg_exp(9, scale=0.5).apply(
        lambda u: laplacian_dense(g) @ u, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --- eg_update -------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 512, 700])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_eg_update_shapes(n, k):
    v = rand(8, (n, k))
    v = v / jnp.linalg.norm(v, axis=0, keepdims=True)
    av = rand(9, (n, k))
    got = eg_ops.mu_eg_update(v, av, 0.05, **I)
    want = eg_ref.mu_eg_update(v, av, 0.05)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_eg_update_matches_solver_step():
    """Fused kernel == solvers.mu_eg_step (the training loop's oracle)."""
    from repro.core.solvers import SolverState, mu_eg_step
    n, k = 384, 5
    v = rand(10, (n, k))
    v = v / jnp.linalg.norm(v, axis=0, keepdims=True)
    av = rand(11, (n, k))
    st_ = SolverState(v=v, step=jnp.zeros((), jnp.int32))
    want = mu_eg_step(st_, av, 0.03).v
    got = eg_ops.mu_eg_update(v, av, 0.03, **I)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 500))
@settings(max_examples=6, deadline=None)
def test_eg_update_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 80)) * 8
    k = int(rng.integers(1, 7))
    lr = float(rng.uniform(0.001, 0.3))
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    v = v / jnp.linalg.norm(v, axis=0, keepdims=True)
    av = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    got = eg_ops.mu_eg_update(v, av, lr, block_n=128, **I)
    want = eg_ref.mu_eg_update(v, av, lr)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_eg_update_preserves_unit_norm():
    n, k = 256, 6
    v = rand(12, (n, k))
    v = v / jnp.linalg.norm(v, axis=0, keepdims=True)
    av = rand(13, (n, k))
    out = eg_ops.mu_eg_update(v, av, 0.1, **I)
    np.testing.assert_allclose(jnp.linalg.norm(out, axis=0), 1.0, atol=1e-5)
