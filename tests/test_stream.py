"""Streaming subsystem: graph store vs rebuilt-Laplacian ground truth,
warm-start reconvergence, incremental-update fallback, label stability,
and the service's one-compiled-step-per-capacity-class invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    graphs, laplacian_dense, laplacian_matvec, make_edge_list, metrics,
    operators,
)
from repro.core.kmeans import cluster_agreement
from repro.core.series import limit_neg_exp
from repro.core.laplacian import spectral_radius_upper_bound
from repro.stream import graph_store as gs
from repro.stream import tracking, updates, warm
from repro.stream.service import (
    ServiceConfig, StreamingService, UnknownSessionError,
)


# ---------------------------------------------------------------------------
# graph store
# ---------------------------------------------------------------------------

def _dense_from_dict(ref: dict, n: int) -> np.ndarray:
    l = np.zeros((n, n), np.float32)
    for (i, j), w in ref.items():
        if w == 0.0:
            continue
        l[i, i] += w
        l[j, j] += w
        l[i, j] -= w
        l[j, i] -= w
    return l


def test_edge_batches_match_rebuilt_laplacian():
    """Random insert/delete/reweight batches == ground-truth rebuild."""
    rng = np.random.default_rng(0)
    n = 12
    g = make_edge_list(np.array([[0, 1], [1, 2], [2, 3]]), n)
    ref = {(0, 1): 1.0, (1, 2): 1.0, (2, 3): 1.0}
    store = gs.from_edge_list(g, capacity=64)
    for step in range(6):
        pairs, ws = [], []
        for _ in range(5):
            i, j = sorted(rng.choice(n, size=2, replace=False).tolist())
            w = float(rng.choice([0.0, 0.5, 1.0, 2.0]))  # 0 => delete
            pairs.append((i, j))
            ws.append(w)
        batch = gs.coalesce_batch(pairs, ws, mode="set", pad_to=8)
        store, _, _ = gs.apply_edge_batch(store, batch, mode="set")
        for (i, j), w in zip(pairs, ws):
            ref[(i, j)] = w  # same last-write-wins semantics
        got = np.asarray(laplacian_dense(gs.as_edge_list(store)))
        np.testing.assert_allclose(got, _dense_from_dict(ref, n), atol=1e-6)
    # live edge count agrees too
    assert int(gs.num_edges(store)) == sum(1 for w in ref.values() if w != 0)


def test_add_mode_accumulates_and_deletes_at_zero():
    g = make_edge_list(np.array([[0, 1]]), 4)
    store = gs.from_edge_list(g, capacity=16)
    b = gs.make_edge_batch([[0, 1]], [2.0], pad_to=4)
    store, dw, _ = gs.apply_edge_batch(store, b, mode="add")
    assert float(dw[0]) == 2.0
    assert int(gs.num_edges(store)) == 1
    b = gs.make_edge_batch([[0, 1]], [-3.0], pad_to=4)
    store, dw, _ = gs.apply_edge_batch(store, b, mode="add")
    assert float(dw[0]) == -3.0
    assert int(gs.num_edges(store)) == 0  # weight hit 0 => slot freed


def test_lazy_degrees_and_radius_bound():
    g = make_edge_list(np.array([[0, 1], [1, 2]]), 4)
    store = gs.from_edge_list(g, capacity=16)
    b = gs.make_edge_batch([[2, 3]], [4.0], pad_to=4)
    store, _, _ = gs.apply_edge_batch(store, b)
    assert bool(store.deg_dirty)  # mutation only marks the cache stale
    store, rho = gs.spectral_radius_upper_bound(store)
    assert not bool(store.deg_dirty)
    np.testing.assert_allclose(float(rho), 10.0)  # node 2: deg 1+4
    exp = np.asarray(jnp.zeros(4).at[store.src].add(store.weight)
                     .at[store.dst].add(store.weight))
    np.testing.assert_allclose(np.asarray(store.deg), exp)


def test_padded_reweight_near_capacity_does_not_drop():
    """Padding/no-op batch entries must not consume free slots or count
    as drops — a reweight on a nearly-full store stays in place."""
    n = 16
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)][:14]
    g = make_edge_list(np.asarray(pairs, np.int32), n)
    store = gs.from_edge_list(g, capacity=16)  # only 2 free slots
    batch = gs.make_edge_batch([pairs[0]], [5.0], pad_to=8)  # 7 pads
    store2, dw, stats = gs.apply_edge_batch(store, batch, mode="set")
    assert int(stats.dropped) == 0
    assert int(stats.matched) == 1
    assert int(stats.inserted) == 0
    assert float(dw[0]) == 4.0
    assert int(gs.num_edges(store2)) == 14


def test_self_loops_dropped_and_padding_sentinel_safe():
    """Self-loop entries must be dropped: a live (0, 0) slot would
    collide with the padding sentinel and be silently deleted by any
    later padded batch."""
    g = make_edge_list(np.array([[0, 1]]), 4)
    store = gs.from_edge_list(g, capacity=16)
    b = gs.make_edge_batch([[0, 0], [2, 3]], [1.0, 1.0], pad_to=4)
    store, _, stats = gs.apply_edge_batch(store, b)
    assert int(gs.num_edges(store)) == 2  # (0,1) and (2,3); no (0,0)
    # a later padded batch must not disturb anything
    b2 = gs.make_edge_batch([[0, 1]], [2.0], pad_to=8)
    store, _, stats2 = gs.apply_edge_batch(store, b2)
    assert int(stats2.matched) == 1
    assert int(gs.num_edges(store)) == 2
    # coalesce path drops self loops too
    cb = gs.coalesce_batch([[3, 3], [1, 2]], [1.0, 1.0], pad_to=4)
    assert int(jnp.sum(cb.weight != 0)) == 1


def test_sparse_sbm_degenerate_blocks():
    """Size-1 blocks and zero sampled edges must still produce a valid,
    isolated-node-free graph with in-range indices."""
    for n, b in [(9, 5), (5, 5)]:
        g, labels = graphs.sparse_sbm_graph(n, b, avg_degree_in=0.0,
                                            avg_degree_out=0.0, seed=0)
        src = np.asarray(g.src)
        dst = np.asarray(g.dst)
        assert src.min() >= 0 and dst.max() < n
        present = np.zeros(n, bool)
        present[src] = True
        present[dst] = True
        assert present.all()


def test_capacity_classes_and_grow():
    assert gs.capacity_class(100) == 256
    assert gs.capacity_class(200) == 512
    g = make_edge_list(np.array([[0, 1]]), 4)
    store = gs.from_edge_list(g, capacity=256)
    grown = gs.grow(store)
    assert grown.capacity == 512
    np.testing.assert_allclose(
        np.asarray(laplacian_dense(gs.as_edge_list(grown))),
        np.asarray(laplacian_dense(gs.as_edge_list(store))), atol=1e-6)


def test_padded_store_feeds_core_operators():
    g, _ = graphs.ring_of_cliques(3, 8)
    store = gs.from_edge_list(g, capacity=256)
    v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 3))
    np.testing.assert_allclose(
        np.asarray(laplacian_matvec(gs.as_edge_list(store), v)),
        np.asarray(laplacian_matvec(g, v)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# warm-started reconvergence
# ---------------------------------------------------------------------------

def _dilated_op(g, degree=7, strength=6.0):
    rho = float(spectral_radius_upper_bound(g))
    s = limit_neg_exp(degree, scale=strength / rho)
    return operators.series_operator(s, operators.edge_matvec(g))


def test_warm_start_reconverges_faster_than_cold():
    """Perturbed SBM: warm-started session needs fewer iterations."""
    g, _ = graphs.sbm_graph(150, 3, p_in=0.3, p_out=0.02, seed=0)
    cfg = warm.WarmConfig(tol=5e-3, chunk=10, max_steps=3000, lr=0.3)
    key = jax.random.PRNGKey(0)
    op = _dilated_op(g)
    state, cold = warm.reconverge(key, op, g.num_nodes, 5, cfg, v_prev=None)
    assert cold["iterations"] > 0 and cold["residual"] <= cfg.tol
    # perturb ~1% of edges, re-solve warm from the previous panel
    rng = np.random.default_rng(1)
    e = g.num_edges
    keep = np.ones(e, bool)
    keep[rng.choice(e, size=max(e // 100, 1), replace=False)] = False
    g2 = make_edge_list(
        np.stack([np.asarray(g.src)[keep], np.asarray(g.dst)[keep]], 1),
        g.num_nodes)
    op2 = _dilated_op(g2)
    _, warm_info = warm.reconverge(key, op2, g.num_nodes, 5, cfg,
                                   v_prev=state.v)
    assert warm_info["warm"]  # restart test must accept the old panel
    assert warm_info["residual"] <= cfg.tol
    assert warm_info["iterations"] < cold["iterations"]


def test_restart_test_rejects_garbage_panel():
    g, _ = graphs.ring_of_cliques(4, 10)
    op = _dilated_op(g)
    # a panel of indicators of WRONG nodes has a large residual
    junk = jnp.eye(g.num_nodes)[:, :4]
    state, info = warm.warm_start_state(
        jax.random.PRNGKey(0), op, g.num_nodes, 4, junk,
        restart_residual=0.05)
    assert not info["warm"]


# ---------------------------------------------------------------------------
# incremental eigen-updates
# ---------------------------------------------------------------------------

def test_first_order_update_tracks_exact_eigh():
    g, _ = graphs.ring_of_cliques(3, 8)
    n, k = g.num_nodes, 4
    l0 = np.asarray(laplacian_dense(g), np.float64)
    lam0, v0 = np.linalg.eigh(l0)
    est = updates.estimate_from_panel(
        lambda v: laplacian_matvec(g, v), jnp.asarray(v0[:, :k], jnp.float32))
    np.testing.assert_allclose(np.asarray(est.lam), lam0[:k], atol=1e-4)
    # tiny reweight of one edge
    src = jnp.asarray([0], jnp.int32)
    dst = jnp.asarray([1], jnp.int32)
    dw = jnp.asarray([0.01], jnp.float32)
    est2 = updates.first_order_update(est, src, dst, dw)
    l1 = l0.copy()
    for i, j, w in [(0, 1, 0.01)]:
        l1[i, i] += w; l1[j, j] += w; l1[i, j] -= w; l1[j, i] -= w
    lam1 = np.linalg.eigh(l1)[0]
    np.testing.assert_allclose(np.asarray(est2.lam), lam1[:k], atol=1e-3)
    assert float(est2.drift) > 0


def test_fallback_triggers_at_drift_threshold():
    lam = jnp.asarray([0.0, 0.1, 0.5, 1.0])
    v = jnp.eye(8)[:, :4]
    cfg = updates.UpdateConfig(fallback_ratio=0.5)
    small = updates.EigenEstimate(lam=lam, v=v, drift=jnp.asarray(0.04))
    big = updates.EigenEstimate(lam=lam, v=v, drift=jnp.asarray(0.06))
    # min gap 0.1, threshold 0.05: drift just below vs just above
    assert not bool(updates.should_fallback(small, cfg))
    assert bool(updates.should_fallback(big, cfg))
    # drift accumulates across batches by the Frobenius bound
    src = jnp.asarray([0], jnp.int32)
    dst = jnp.asarray([1], jnp.int32)
    dw = jnp.asarray([3.0], jnp.float32)
    est2 = updates.first_order_update(small, src, dst, dw)
    np.testing.assert_allclose(float(est2.drift), 0.04 + 6.0, rtol=1e-5)
    assert bool(updates.should_fallback(est2, cfg))


def test_delta_norm_bound_covers_hub_batches():
    """The drift bound must dominate ||ΔL||_F even when batch edges share
    an endpoint (diagonal contributions stack at the hub)."""
    src = jnp.asarray([0, 0], jnp.int32)
    dst = jnp.asarray([1, 2], jnp.int32)
    dw = jnp.asarray([1.0, 1.0], jnp.float32)
    dl = np.zeros((3, 3))
    for s, d, w in [(0, 1, 1.0), (0, 2, 1.0)]:
        dl[s, s] += w; dl[d, d] += w; dl[s, d] -= w; dl[d, s] -= w
    true_norm = np.linalg.norm(dl)  # sqrt(10) ≈ 3.162
    bound = float(updates.delta_norm_bound(dw))
    assert bound >= true_norm - 1e-6, (bound, true_norm)


# ---------------------------------------------------------------------------
# label tracking
# ---------------------------------------------------------------------------

def test_label_tracking_stable_under_permutation_and_noop():
    rng = np.random.default_rng(0)
    labels = jnp.asarray(rng.integers(0, 3, size=40))
    tracker = tracking.LabelTracker(3)
    first = tracker.update(labels)
    np.testing.assert_array_equal(np.asarray(first), np.asarray(labels))
    # a re-solve that permutes cluster ids must map back to stable ids
    perm = jnp.asarray([2, 0, 1])
    relabelled = perm[labels]
    stable = tracker.update(relabelled)
    np.testing.assert_array_equal(np.asarray(stable), np.asarray(labels))
    # and a genuine no-op update keeps ids verbatim
    stable2 = tracker.update(stable)
    np.testing.assert_array_equal(np.asarray(stable2), np.asarray(labels))


# ---------------------------------------------------------------------------
# service
# ---------------------------------------------------------------------------

SVC_CFG = ServiceConfig(k=4, num_clusters=3, degree=7, steps_per_tick=25,
                        lr=0.3, tol=5e-3, dilation_strength=6.0)


@pytest.fixture(scope="module")
def eight_session_service():
    svc = StreamingService(SVC_CFG)
    truths = {}
    for i in range(8):
        g, lab = graphs.sbm_graph(60, 3, p_in=0.4, p_out=0.02, seed=i)
        svc.add_graph(f"g{i}", g, num_clusters=3, edge_capacity=1024)
        truths[f"g{i}"] = lab
    svc.run_until_converged(max_ticks=120)
    return svc, truths


def test_service_eight_sessions_share_group_logarithmic_compiles(
        eight_session_service):
    svc, truths = eight_session_service
    # all 8 sessions share ONE (capacity class, degree) tick group for
    # the entire lifecycle — per-session lr/scale and the scheduler's
    # tick multiplier are traced, so no per-session (or per-multiplier)
    # programs ever compile.  Distinct compiles only along the pow2
    # occupancy buckets (groups shrink as sessions converge — converged
    # sessions cost zero device work): <= 1 + log2(8).
    group_keys = {key for key, _ in svc._compiled}
    assert len(group_keys) == 1
    occs = {occ for _, occ in svc._compiled}
    assert all(occ == 1 << (occ.bit_length() - 1) for occ in occs)
    assert max(occs) <= 8
    assert svc.compile_count <= 4
    for sid in truths:
        assert svc.session_info(sid)["converged"], sid


def test_service_labels_recover_communities(eight_session_service):
    svc, truths = eight_session_service
    agree = [
        float(cluster_agreement(jnp.asarray(svc.labels(sid)),
                                jnp.asarray(truths[sid]), 3))
        for sid in truths
    ]
    assert np.mean(agree) > 0.9, agree


def test_service_noop_update_keeps_labels_and_convergence(
        eight_session_service):
    svc, truths = eight_session_service
    before = svc.labels("g0")
    # rewrite an existing edge to its current weight: realized dw == 0
    src, dst, w = svc.live_edges("g0")
    stats = svc.apply_updates("g0", [[int(src[0]), int(dst[0])]],
                              [float(w[0])], mode="set")
    info = svc.session_info("g0")
    assert int(stats.matched) == 1
    assert info["converged"]  # no-op must not trigger a re-solve
    assert info["fallbacks"] == 0
    after = svc.labels("g0")
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))


def test_service_update_fallback_and_warm_reconverge(eight_session_service):
    svc, truths = eight_session_service
    src, dst, _ = svc.live_edges("g1")
    rng = np.random.default_rng(2)
    sel = rng.choice(len(src), size=len(src) // 4, replace=False)
    stats = svc.apply_updates(
        "g1", np.stack([src[sel], dst[sel]], 1), np.zeros(len(sel)),
        mode="set")
    info = svc.session_info("g1")
    assert info["fallbacks"] == 1 and not info["converged"]
    ticks_before = info["ticks"]
    svc.run_until_converged(max_ticks=120)
    info = svc.session_info("g1")
    assert info["converged"]
    # warm restart: reconvergence is no costlier than the cold admission
    # solve despite the 25% perturbation (the >=3x iteration saving at 1%
    # perturbation is asserted by benchmarks/bench_stream.py, where the
    # tick granularity can resolve it)
    assert info["ticks"] - ticks_before <= ticks_before
    # the whole update/reconverge cycle stayed inside the one (class,
    # degree) tick group — no per-session or per-update recompiles,
    # only pow2 occupancy buckets
    assert len({key for key, _ in svc._compiled}) == 1


def test_service_buffer_overflow_grows_capacity_class():
    svc = StreamingService(dataclasses.replace(SVC_CFG, steps_per_tick=5))
    g, _ = graphs.ring_of_cliques(3, 6)
    svc.add_graph("tiny", g, num_clusters=3, edge_capacity=64)
    n = g.num_nodes
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]
    stats = svc.apply_updates("tiny", pairs, np.full(len(pairs), 0.5),
                              mode="set")
    info = svc.session_info("tiny")
    assert info["edge_capacity"] == 256  # grew to the next ladder class
    assert int(stats.dropped) == 0
    # the near-complete reweighted graph has no cluster structure, so we
    # assert growth correctness, not clustering convergence, here
    assert info["num_edges"] == len(pairs)


def test_service_overflow_grows_multiple_classes_without_loss():
    """A batch bigger than one ladder step keeps growing until nothing
    drops — no silent edge loss."""
    svc = StreamingService(dataclasses.replace(SVC_CFG, steps_per_tick=5))
    g, _ = graphs.ring_of_cliques(4, 10)  # n=40, 184 edges
    svc.add_graph("burst", g, num_clusters=3, edge_capacity=256)
    n = g.num_nodes
    pairs = [(i, j) for i in range(n) for j in range(i + 1, n)]  # 780
    stats = svc.apply_updates("burst", pairs, np.full(len(pairs), 0.5),
                              mode="set")
    info = svc.session_info("burst")
    assert int(stats.dropped) == 0
    assert info["edge_capacity"] == 1024  # 256 -> 512 -> 1024 (two steps)
    assert info["num_edges"] == len(pairs)


def test_add_graph_rejects_underprovisioned_k():
    svc = StreamingService(SVC_CFG)  # k=4, drop_trivial=True
    g, _ = graphs.ring_of_cliques(3, 6)
    with pytest.raises(ValueError, match="tracked"):
        svc.add_graph("bad", g, num_clusters=4)  # needs 5 > k=4


@pytest.mark.parametrize("backend", ["segment", "pallas"])
def test_edgeless_admission_recovers_after_updates(backend):
    """Regression: a graph admitted with zero edges has rho == rho_ub
    == 0; the ratio-based rho rescale must re-anchor on the fresh bound
    when edges arrive instead of pinning rho at 0 forever (which blew
    the dilation scale up to ~1/eps and NaN'd the panel)."""
    from repro.core.laplacian import make_edge_list

    svc = StreamingService(dataclasses.replace(
        SVC_CFG, steps_per_tick=5, backend=backend, tick_block_n=32))
    g0 = make_edge_list(np.zeros((0, 2), np.int64), 40)
    svc.add_graph("empty", g0, num_clusters=3, edge_capacity=256)
    svc.apply_updates("empty", [[0, 1], [1, 2], [2, 3], [3, 0]],
                      [1.0, 1.0, 1.0, 1.0])
    res = svc.tick()["empty"]
    sess = svc._sessions["empty"]
    assert np.isfinite(res)
    assert sess.rho > 0.0
    assert bool(jnp.all(jnp.isfinite(sess.v)))


# ---------------------------------------------------------------------------
# typed session errors, converged re-entry, per-session multipliers
# ---------------------------------------------------------------------------

def test_unknown_session_raises_typed_error():
    """Unknown/evicted sids raise UnknownSessionError (a KeyError
    subclass, so pre-typed callers keep working) from every session
    accessor — and evict is NOT idempotent."""
    svc = StreamingService(dataclasses.replace(SVC_CFG, steps_per_tick=5))
    g, _ = graphs.ring_of_cliques(3, 6)
    svc.add_graph("here", g, num_clusters=3)
    for fn in (svc.labels, svc.session_info, svc.evict, svc.panel,
               svc.live_edges):
        with pytest.raises(UnknownSessionError, match="never"):
            fn("never")
    with pytest.raises(UnknownSessionError):
        svc.apply_updates("never", [[0, 1]], [1.0])
    assert issubclass(UnknownSessionError, KeyError)
    summary = svc.evict("here")  # first evict succeeds...
    assert summary["n"] == g.num_nodes
    with pytest.raises(UnknownSessionError, match="here"):
        svc.evict("here")  # ...the double evict reports the id as gone
    with pytest.raises(UnknownSessionError, match="here"):
        svc.labels("here")


def test_converged_session_reenters_ticking_after_update():
    """Regression: an edge batch that moves a CONVERGED session's
    residual back above tolerance must re-enter it into its tick group
    on the next tick() — before the fix the first-order update path
    marked the panel patched and the session stayed 'converged' with a
    stale residual forever (no fallback, no ticks)."""
    cfg = dataclasses.replace(SVC_CFG, steps_per_tick=25, tol=5e-4)
    svc = StreamingService(cfg)
    g, _ = graphs.sbm_graph(60, 3, p_in=0.4, p_out=0.02, seed=3)
    svc.add_graph("s", g, num_clusters=3, edge_capacity=1024)
    assert svc.run_until_converged(max_ticks=400) < 400
    info = svc.session_info("s")
    assert info["converged"] and info["residual"] <= cfg.tol
    # a small real perturbation: two weak cross-community edges, well
    # under the drift bound (2*sum|dw| = 0.08 << 0.5 * ~0.44 min gap)
    # so the first-order path handles it, yet the patched panel's
    # re-measured residual lands back above the tight tolerance
    svc.apply_updates("s", [[0, 25], [5, 30]], [0.02, 0.02], mode="add")
    info = svc.session_info("s")
    assert info["fallbacks"] == 0  # cheap path, not a re-solve
    assert not info["converged"]  # re-entered: residual re-measured
    assert info["residual"] > cfg.tol
    ticks_before = info["ticks"]
    assert svc.run_until_converged(max_ticks=400) < 400
    info = svc.session_info("s")
    assert info["converged"] and info["ticks"] > ticks_before


def test_mixed_contraction_group_schedules_per_session():
    """Regression: the residual-decay multiplier is PER SESSION — a
    near-converged member no longer drags far-from-converged peers in
    the same tick group down to multiplier 1 (the old group-min)."""
    cfg = dataclasses.replace(SVC_CFG, steps_per_tick=5,
                              max_tick_multiplier=8, eval_payoff=2.0)
    svc = StreamingService(cfg)
    for i, sid in enumerate(("near", "far")):
        g, _ = graphs.sbm_graph(60, 3, p_in=0.4, p_out=0.02, seed=40 + i)
        svc.add_graph(sid, g, num_clusters=3, edge_capacity=1024)
    near, far = svc._sessions["near"], svc._sessions["far"]
    # pin the forecasts: 'near' is one plain tick from tolerance,
    # 'far' needs far more than eval_payoff plain ticks
    near.residual, near.rate = cfg.tol * 1.5, 0.8
    far.residual, far.rate = 0.5, 0.995
    mults = svc._tick_multipliers([near, far])
    assert mults[0] == 1  # the old min() would have forced BOTH to 1
    assert mults[1] == cfg.max_tick_multiplier
    before = svc.multiplied_ticks
    svc.tick()
    # the mixed group still counted as a multiplied (stretched) tick,
    # through the one shared compiled program
    assert svc.multiplied_ticks == before + 1
    assert len({key for key, _ in svc._compiled}) == 1
