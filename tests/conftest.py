"""Shared test-lane plumbing.

Two concerns live here:

* ``distributed`` marker — tests that only mean anything on a real
  multi-device mesh (collectives over >= 2 shards).  They auto-skip
  when the process sees fewer than 2 devices, and run for real in the
  CI lane ``scripts/ci.sh`` spawns with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (any test can
  be run that way by hand, too).  Plain tier-1 runs stay single-device
  and simply report the skips.

* ``--stochastic-reruns=N`` — triage knob for the ``stochastic`` suite.
  Those tests use FIXED PRNG seeds (the seed-audit test enforces that)
  and are deterministic run-to-run, so a failure is a real regression,
  not sampling noise; rerunning under this flag is how you PROVE that
  during triage: a fixed-seed test that fails once fails N times, while
  a test accidentally drawing entropy from an unseeded source flips.
  Reruns re-execute failing stochastic tests up to N extra times and
  report the LAST outcome.
"""
from __future__ import annotations

import jax
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--stochastic-reruns",
        action="store",
        type=int,
        default=0,
        help="re-run failing `stochastic`-marked tests up to N extra "
             "times (fixed-seed tests must fail deterministically; a "
             "flip under reruns means a test is drawing unseeded "
             "entropy — see README Verify)",
    )


def pytest_collection_modifyitems(config, items):
    if jax.device_count() >= 2:
        return
    skip = pytest.mark.skip(
        reason="needs >= 2 devices; run under XLA_FLAGS="
               "--xla_force_host_platform_device_count=8 "
               "(scripts/ci.sh distributed lane)")
    for item in items:
        if "distributed" in item.keywords:
            item.add_marker(skip)


@pytest.hookimpl(tryfirst=True)
def pytest_runtest_protocol(item, nextitem):
    reruns = item.config.getoption("--stochastic-reruns")
    if not reruns or "stochastic" not in item.keywords:
        return None  # default protocol
    from _pytest.runner import runtestprotocol

    item.ihook.pytest_runtest_logstart(
        nodeid=item.nodeid, location=item.location)
    for attempt in range(reruns + 1):
        reports = runtestprotocol(item, nextitem=nextitem, log=False)
        if not any(r.failed for r in reports) or attempt == reruns:
            for r in reports:
                item.ihook.pytest_runtest_logreport(report=r)
            break
    item.ihook.pytest_runtest_logfinish(
        nodeid=item.nodeid, location=item.location)
    return True
