"""Distributed SPED operators (shard_map) — single-device mesh here;
the 512-device production mesh is exercised by launch/dryrun.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (
    SolverConfig, build_edge_incidence, laplacian_dense, limit_neg_exp,
    run_solver,
)
from repro.core import distributed, graphs, metrics, operators, walks


@pytest.fixture(scope="module")
def mesh():
    dev = np.array(jax.devices()).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


@pytest.fixture(scope="module")
def graph():
    g, labels = graphs.clique_graph(120, 3, seed=0)
    return g, laplacian_dense(g)


def test_sharded_matvec_matches_dense(mesh, graph):
    g, L = graph
    gp = distributed.pad_edges_for_mesh(g, mesh.shape["data"])
    mv = distributed.sharded_laplacian_matvec(mesh)
    v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 4))
    np.testing.assert_allclose(
        mv(gp.src, gp.dst, gp.weight, v), L @ v, rtol=1e-4, atol=1e-4)


def test_edge_padding_adds_no_mass(graph):
    g, L = graph
    gp = distributed.pad_edges_for_mesh(g, 8)
    assert gp.num_edges % 8 == 0
    from repro.core import laplacian_matvec
    v = jax.random.normal(jax.random.PRNGKey(1), (g.num_nodes, 2))
    np.testing.assert_allclose(
        laplacian_matvec(gp, v), L @ v, rtol=1e-4, atol=1e-4)


def test_distributed_series_operator_matches_local(mesh, graph):
    g, L = graph
    s = limit_neg_exp(51, scale=4.0 / float(2 * jnp.max(jnp.diag(L))))
    op_d = distributed.distributed_series_operator(mesh, g, s)
    op_l = operators.series_operator(s, operators.dense_matvec(L))
    v = jax.random.normal(jax.random.PRNGKey(2), (g.num_nodes, 3))
    np.testing.assert_allclose(op_d(v), op_l(v), rtol=1e-3, atol=1e-3)


def test_distributed_minibatch_converges(mesh, graph):
    g, L = graph
    rho = float(2 * jnp.max(jnp.diag(L)))
    s = limit_neg_exp(51, scale=6.0 / rho)
    op = distributed.distributed_minibatch_operator(
        mesh, g, s, batch_edges_per_device=512)
    k = 3
    _, v_star = metrics.ground_truth_bottom_k(L, k)
    cfg = SolverConfig(method="mu_eg", lr=0.1, steps=800, eval_every=100, k=k)
    _, tr = run_solver(op, g.num_nodes, cfg, v_star=v_star, stochastic=True)
    assert float(tr.subspace_error[-1]) < 0.08


def test_distributed_walk_operator_matches_expectation(mesh):
    g, _ = graphs.ring_of_cliques(3, 4)
    inc = build_edge_incidence(g)
    L = np.asarray(laplacian_dense(g))
    coeffs = (0.0, 0.0, 1.0)  # pure L^2
    op = distributed.distributed_walk_operator(
        mesh, g, inc, coeffs, lambda_star=0.0, walkers_per_device=100_000)
    v = jnp.eye(g.num_nodes)
    est = -np.asarray(op(jax.random.PRNGKey(0), v))  # op = 0 - P(L)
    want = L @ L
    rel = np.linalg.norm(est - want) / np.linalg.norm(want)
    assert rel < 0.08, rel
