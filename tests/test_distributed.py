"""Distributed SPED operators (shard_map).

The mesh fixture spans EVERY device the process sees: plain tier-1 runs
are single-device (collectives degenerate to copies), while the
scripts/ci.sh distributed lane re-runs this file in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the psums
actually cross shard boundaries.  Tests marked ``distributed`` REQUIRE
>= 2 devices (conftest skips them below that) and pin the acceptance
contract of sharded serving: sharded == single-device to <= 1e-5 for
matvecs, fused series programs, full solves, and streaming ticks, on
weighted / capacity-padded / non-aligned graphs, including per-shard
node blockings and all-padding shards.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.compat import default_edge_mesh
from repro.core import (
    SolverConfig, backend, build_edge_incidence, laplacian_dense,
    limit_neg_exp, run_solver,
)
from repro.core import distributed, graphs, metrics, operators, program, solvers
from repro.core import laplacian as lap
from repro.kernels.edge_spmm import ops as es_ops

TOL = 1e-5


@pytest.fixture(scope="module")
def mesh():
    """("data", "model") mesh over ALL local devices — 1 in tier-1,
    8 in the distributed CI lane (the old fixture pinned 1x1, which
    made every collective a no-op even under the lane)."""
    return default_edge_mesh()


@pytest.fixture(scope="module")
def graph():
    g, labels = graphs.clique_graph(120, 3, seed=0)
    return g, laplacian_dense(g)


def _rand_graph(seed: int, n: int, e: int) -> lap.EdgeList:
    rng = np.random.default_rng(seed)
    edges = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], axis=1)
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.uniform(0.1, 2.0, size=len(edges)).astype(np.float32)
    return lap.make_edge_list(edges, n, weights=w)


def _panel(seed: int, n: int, k: int) -> jax.Array:
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(n, k)), jnp.float32)


# weighted / capacity-padded / non-aligned (n, E not block multiples)
CASES = {
    "weighted": lambda: _rand_graph(0, 96, 300),
    "capacity_padded": lambda: lap.pad_edge_list(_rand_graph(1, 96, 300), 512),
    "non_aligned": lambda: _rand_graph(2, 301, 517),
}


def test_mesh_spans_all_devices(mesh):
    """The lane's reason to exist: on 8 virtual devices the edge axis
    really holds 8 shards (a 1x1 mesh would silently test nothing)."""
    assert mesh.shape["data"] == jax.device_count()


def test_sharded_matvec_matches_dense(mesh, graph):
    g, L = graph
    gp = distributed.pad_edges_for_mesh(g, mesh.shape["data"])
    mv = distributed.sharded_laplacian_matvec(mesh)
    v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 4))
    np.testing.assert_allclose(
        mv(gp.src, gp.dst, gp.weight, v), L @ v, rtol=1e-4, atol=1e-4)


def test_edge_padding_adds_no_mass(graph):
    g, L = graph
    gp = distributed.pad_edges_for_mesh(g, 8)
    assert gp.num_edges % 8 == 0
    from repro.core import laplacian_matvec
    v = jax.random.normal(jax.random.PRNGKey(1), (g.num_nodes, 2))
    np.testing.assert_allclose(
        laplacian_matvec(gp, v), L @ v, rtol=1e-4, atol=1e-4)


def test_distributed_series_operator_matches_local(mesh, graph):
    g, L = graph
    s = limit_neg_exp(51, scale=4.0 / float(2 * jnp.max(jnp.diag(L))))
    op_d = distributed.distributed_series_operator(mesh, g, s)
    op_l = operators.series_operator(s, operators.dense_matvec(L))
    v = jax.random.normal(jax.random.PRNGKey(2), (g.num_nodes, 3))
    np.testing.assert_allclose(op_d(v), op_l(v), rtol=1e-3, atol=1e-3)


def test_distributed_minibatch_converges(mesh, graph):
    g, L = graph
    rho = float(2 * jnp.max(jnp.diag(L)))
    s = limit_neg_exp(51, scale=6.0 / rho)
    op = distributed.distributed_minibatch_operator(
        mesh, g, s, batch_edges_per_device=512)
    k = 3
    _, v_star = metrics.ground_truth_bottom_k(L, k)
    cfg = SolverConfig(method="mu_eg", lr=0.1, steps=800, eval_every=100, k=k)
    _, tr = run_solver(op, g.num_nodes, cfg, v_star=v_star, stochastic=True)
    assert float(tr.subspace_error[-1]) < 0.08


def test_distributed_walk_operator_matches_expectation(mesh):
    g, _ = graphs.ring_of_cliques(3, 4)
    inc = build_edge_incidence(g)
    L = np.asarray(laplacian_dense(g))
    coeffs = (0.0, 0.0, 1.0)  # pure L^2
    # ~100k walks TOTAL regardless of device count (pmean averages the
    # per-device estimates, so the total sample budget sets the error)
    per_device = max(100_000 // jax.device_count(), 12_500)
    op = distributed.distributed_walk_operator(
        mesh, g, inc, coeffs, lambda_star=0.0, walkers_per_device=per_device)
    v = jnp.eye(g.num_nodes)
    est = -np.asarray(op(jax.random.PRNGKey(0), v))  # op = 0 - P(L)
    want = L @ L
    rel = np.linalg.norm(est - want) / np.linalg.norm(want)
    assert rel < 0.08, rel


# ---------------------------------------------------------------------------
# per-shard node blockings (host-side: run everywhere, no mesh needed)
# ---------------------------------------------------------------------------

def test_sharded_blocking_shares_one_layout():
    """All shards carry identical static shapes and a shared
    pow2-snapped chunk count (the shard_map shape contract)."""
    g = CASES["non_aligned"]()
    gp = distributed.pad_edges_for_mesh(g, 8)
    sb = backend.sharded_blocking_for(gp, 8, block_n=64)
    assert sb.num_shards == 8
    assert sb.num_chunks == es_ops.next_pow2(sb.num_chunks)
    assert sb.u_local.shape == sb.other.shape == sb.weight.shape
    assert sb.u_local.shape[0] == 8 and sb.deg.shape[0] == 8


def test_sharded_blocking_matches_dense_per_shard_sum():
    """sum_s (deg_s * v - A_s v) == L v: the per-shard decomposition
    reconstructs the matvec exactly (no double-counted diagonal)."""
    for case in sorted(CASES):
        g = CASES[case]()
        L = np.asarray(laplacian_dense(g))
        v = _panel(3, g.num_nodes, 4)
        for num_shards in (1, 4, 8):
            gp = distributed.pad_edges_for_mesh(g, num_shards)
            sb = backend.sharded_blocking_for(gp, num_shards, block_n=64)
            acc = np.zeros_like(np.asarray(v))
            for s in range(num_shards):
                acc += np.asarray(es_ops.edge_spmm_blocked(
                    sb.shard(s), v, interpret=True))
            np.testing.assert_allclose(acc, L @ v, rtol=1e-5, atol=1e-5)


def test_sharded_blocking_rejects_unbalanced_buffer():
    g = CASES["weighted"]()  # num_edges not a multiple of 7
    assert g.num_edges % 7 != 0
    with pytest.raises(ValueError, match="pad_edges_for_mesh"):
        backend.sharded_blocking_for(g, 7)


def test_all_padding_shard_exact_zeros():
    """A shard whose slice is pure capacity padding must contribute
    EXACT zeros (not NaN) on both backends — the sharded sibling of
    PR 3's zero-edge pallas fix."""
    g = lap.make_edge_list(np.array([[0, 1], [1, 2], [2, 3]]), 40)
    gp = distributed.pad_edges_for_mesh(g, 8)  # shards 3..7 all padding
    sb = backend.sharded_blocking_for(gp, 8, block_n=16)
    v = _panel(4, 40, 3)
    per = gp.num_edges // 8
    for s in (3, 7):
        # pallas node-blocked path
        out = np.asarray(es_ops.edge_spmm_blocked(
            sb.shard(s), v, interpret=True))
        assert not np.isnan(out).any()
        np.testing.assert_array_equal(out, 0.0)
        # pallas one-hot path on the raw shard slice
        sl = slice(s * per, (s + 1) * per)
        out = np.asarray(es_ops.edge_spmm(
            gp.src[sl], gp.dst[sl], gp.weight[sl], v, interpret=True))
        assert not np.isnan(out).any()
        np.testing.assert_array_equal(out, 0.0)
        # segment path on the same slice
        out = np.asarray(lap.edge_matvec_arrays(
            gp.src[sl], gp.dst[sl], gp.weight[sl], v))
        np.testing.assert_array_equal(out, 0.0)


def test_edgeless_store_sharded_blocking():
    """Every shard all-padding (edgeless admission): the layout still
    builds with the uniform chunk count and zero degrees."""
    g = lap.make_edge_list(np.zeros((0, 2), np.int64), 32)
    gp = distributed.pad_edges_for_mesh(lap.pad_edge_list(g, 64), 8)
    sb = backend.sharded_blocking_for(gp, 8, block_n=16)
    # CSR chunk layout: every block owns >= 1 chunk even when edgeless
    assert sb.num_chunks == es_ops.next_pow2(sb.num_blocks)
    v = _panel(5, 32, 2)
    for s in range(8):
        out = np.asarray(es_ops.edge_spmm_blocked(
            sb.shard(s), v, interpret=True))
        np.testing.assert_array_equal(out, 0.0)


# ---------------------------------------------------------------------------
# sharded == single-device equivalence (the distributed lane's contract)
# ---------------------------------------------------------------------------

@pytest.mark.distributed
@pytest.mark.parametrize("case", sorted(CASES))
def test_sharded_matvec_equivalence(mesh, case):
    """Sharded raw-array matvec == single-device segment, per backend."""
    g = CASES[case]()
    gp = distributed.pad_edges_for_mesh(g, mesh.shape["data"])
    v = _panel(6, g.num_nodes, 4)
    want = operators.edge_matvec(g, backend="segment")(v)
    for b in ("segment", "pallas"):
        got = distributed.sharded_laplacian_matvec(mesh, backend=b)(
            gp.src, gp.dst, gp.weight, v)
        np.testing.assert_allclose(got, want, rtol=TOL, atol=TOL)


@pytest.mark.distributed
@pytest.mark.parametrize("case", sorted(CASES))
def test_sharded_blocked_matvec_equivalence(mesh, case):
    """Per-shard NODE-BLOCKED sharded matvec == single-device segment —
    the layout that carries the sharded pallas path past
    ONE_HOT_NODE_LIMIT (forced small block_n exercises it at test n)."""
    g = CASES[case]()
    num_shards = distributed.num_edge_shards(mesh)
    gp = distributed.pad_edges_for_mesh(g, num_shards)
    sb = backend.sharded_blocking_for(gp, num_shards, block_n=64)
    v = _panel(7, g.num_nodes, 4)
    want = operators.edge_matvec(g, backend="segment")(v)
    got = distributed.sharded_blocked_matvec(mesh, sb)(v)
    np.testing.assert_allclose(got, want, rtol=TOL, atol=TOL)


@pytest.mark.distributed
@pytest.mark.parametrize("backend_name", ["segment", "pallas"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_sharded_fused_series_equivalence(mesh, case, backend_name):
    """The one-shard_map fused series program == local series operator."""
    g = CASES[case]()
    rho = float(lap.spectral_radius_upper_bound(g))
    s = limit_neg_exp(7, scale=1.2 / rho)
    op_d = distributed.distributed_series_operator(
        mesh, g, s, backend=backend_name)
    op_l = operators.edge_series_operator(g, s, backend="segment")
    v = _panel(8, g.num_nodes, 4)
    np.testing.assert_allclose(op_d(v), op_l(v), rtol=TOL, atol=TOL)


@pytest.mark.distributed
def test_sharded_blocked_series_equivalence(mesh):
    """Forced per-shard blocking through the series program (the
    past-the-one-hot-limit configuration, at test scale)."""
    g = CASES["non_aligned"]()
    rho = float(lap.spectral_radius_upper_bound(g))
    s = limit_neg_exp(9, scale=1.0 / rho)
    op_d = distributed.distributed_series_operator(
        mesh, g, s, backend="pallas", block_n=64)
    op_l = operators.edge_series_operator(g, s, backend="segment")
    v = _panel(9, g.num_nodes, 3)
    np.testing.assert_allclose(op_d(v), op_l(v), rtol=TOL, atol=TOL)


@pytest.mark.distributed
def test_sharded_full_solve_equivalence(mesh):
    """Whole-solve: identical panels after a short run through the
    sharded series program vs the local segment operator."""
    g = CASES["weighted"]()
    rho = float(lap.spectral_radius_upper_bound(g))
    s = limit_neg_exp(7, scale=1.2 / rho)
    cfg = solvers.SolverConfig(method="mu_eg", lr=0.3, steps=10,
                               eval_every=5, k=4, seed=0)
    outs = {}
    for name, op in (
        ("local", operators.edge_series_operator(g, s, backend="segment")),
        ("sharded", distributed.distributed_series_operator(
            mesh, g, s, backend="segment")),
        ("sharded_pallas", distributed.distributed_series_operator(
            mesh, g, s, backend="pallas")),
    ):
        state, _ = solvers.run_solver(op, g.num_nodes, cfg)
        outs[name] = state.v
    for name in ("sharded", "sharded_pallas"):
        err = float(jnp.max(jnp.abs(outs[name] - outs["local"])))
        assert err <= TOL, (name, err)


def test_distributed_solve_routes_through_unified_program(mesh):
    """core.distributed.distributed_solve == run_solver over the local
    operator: the distributed layer's whole-series solve runs THE same
    step construction (core.program) as every other deployment shape."""
    g = CASES["weighted"]()
    rho = float(lap.spectral_radius_upper_bound(g))
    s = limit_neg_exp(7, scale=1.2 / rho)
    cfg = solvers.SolverConfig(method="mu_eg", lr=0.3, steps=10,
                               eval_every=5, k=4, seed=0)
    st_d, _ = distributed.distributed_solve(mesh, g, s, cfg,
                                            backend="segment")
    op_l = operators.edge_series_operator(g, s, backend="segment")
    st_l, _ = run_solver(op_l, g.num_nodes, cfg)
    assert float(jnp.max(jnp.abs(st_d.v - st_l.v))) <= TOL


@pytest.mark.distributed
def test_sharded_probe_matches_single_device(mesh):
    """Sharded SLQ == single-device SLQ (same keys, psum'd matvec)."""
    from repro.spectral import probes

    g = CASES["weighted"]()
    gp = distributed.pad_edges_for_mesh(
        g, distributed.num_edge_shards(mesh))
    key = jax.random.PRNGKey(11)
    n_real = jnp.asarray(g.num_nodes, jnp.int32)
    ps = probes.probe_edge_arrays(
        gp.src, gp.dst, gp.weight, key, n_real, num_nodes=g.num_nodes)
    pd = probes.probe_sharded_edge_arrays(
        mesh, gp.src, gp.dst, gp.weight, key, n_real,
        num_nodes=g.num_nodes)
    assert abs(float(ps.lambda_max) - float(pd.lambda_max)) <= 1e-3
    np.testing.assert_allclose(ps.ritz, pd.ritz, atol=1e-3)


# ---------------------------------------------------------------------------
# sharded streaming ticks (ServiceConfig(mesh=...))
# ---------------------------------------------------------------------------

def _service_graphs():
    g_w, _ = graphs.sbm_graph(120, 3, p_in=0.35, p_out=0.03, seed=1)
    return {
        "weighted": CASES["weighted"](),
        "capacity_padded": g_w,  # admission pads to a capacity class
        "non_aligned": CASES["non_aligned"](),
    }


@pytest.mark.distributed
def test_sharded_streaming_tick_equivalence(mesh):
    """Sharded class ticks == single-device segment ticks to <= 1e-5 on
    weighted, capacity-padded, and non-aligned graphs, for BOTH sharded
    backends; updates invalidate + rebuild the per-shard blockings and
    the compiled-program count stays one per (class, layout, bucket)."""
    from repro.stream.service import ServiceConfig, StreamingService

    common = dict(k=5, num_clusters=3, degree=7, steps_per_tick=5,
                  lr=0.3, seed=0)
    single = StreamingService(ServiceConfig(backend="segment", **common))
    shard_seg = StreamingService(ServiceConfig(
        backend="segment", mesh=mesh, **common))
    shard_pal = StreamingService(ServiceConfig(
        backend="pallas", mesh=mesh, tick_block_n=32, **common))
    svcs = (single, shard_seg, shard_pal)
    for sid, g in _service_graphs().items():
        for svc in svcs:
            svc.add_graph(sid, g)
    res = [svc.tick() for svc in svcs]
    for sid in _service_graphs():
        for r in res[1:]:
            assert abs(r[sid] - res[0][sid]) <= TOL, sid
        for svc in svcs[1:]:
            err = float(jnp.max(jnp.abs(
                svc._sessions[sid].v - single._sessions[sid].v)))
            assert err <= TOL, (sid, err)
    # shard-balanced capacities: every store divides into the mesh
    num_shards = distributed.num_edge_shards(mesh)
    for svc in (shard_seg, shard_pal):
        for sess in svc._sessions.values():
            assert sess.store.capacity % num_shards == 0
    # updates stale the per-shard layouts; ticks stay glued afterwards
    for svc in svcs:
        svc.apply_updates("weighted", [[0, 5], [1, 7]], [1.0, 1.0])
    assert shard_pal._sessions["weighted"].sharded_blocking is None
    for svc in svcs:
        svc.tick()
    assert shard_pal._sessions["weighted"].sharded_blocking is not None
    for svc in svcs[1:]:
        err = float(jnp.max(jnp.abs(
            svc._sessions["weighted"].v - single._sessions["weighted"].v)))
        assert err <= TOL, err
    # one compiled program per (class, layout, occupancy bucket)
    assert shard_pal.compile_count == len(
        {s.group_key for s in shard_pal._sessions.values()})


@pytest.mark.distributed
def test_sharded_edgeless_admission_ticks(mesh):
    """An edgeless session (every shard all-padding) must tick to exact
    finite panels — no NaN — on both sharded backends."""
    from repro.stream.service import ServiceConfig, StreamingService

    g = lap.make_edge_list(np.zeros((0, 2), np.int64), 40)
    for b, extra in (("segment", {}), ("pallas", {"tick_block_n": 16})):
        svc = StreamingService(ServiceConfig(
            backend=b, mesh=mesh, k=4, num_clusters=3, degree=5,
            steps_per_tick=3, seed=0, **extra))
        svc.add_graph("empty", g)
        svc.tick()
        v = np.asarray(svc._sessions["empty"].v)
        assert np.isfinite(v).all(), b


# ---------------------------------------------------------------------------
# PANEL (model) sharded ticks — one fused rows+gram collective per step
# ---------------------------------------------------------------------------

def _model_mesh(num_shards: int) -> Mesh:
    devs = jax.devices()
    if len(devs) < num_shards:
        pytest.skip(f"needs {num_shards} devices")
    return Mesh(np.array(devs[:num_shards]).reshape(1, num_shards),
                ("data", "model"))


def _model_tick_args(g, num_shards, *, block_n=32, k=4, seed=7,
                     c=0.05, lr=0.2):
    """One-session (G=1) argument pack for build_tick_model_sharded."""
    mb = backend.build_model_sharded_blocking(
        np.asarray(g.src), np.asarray(g.dst), np.asarray(g.weight),
        g.num_nodes, num_shards, block_n=block_n)
    v = _panel(seed, g.num_nodes, k)
    args = (mb.u_local[None], mb.other[None], mb.weight[None],
            mb.chunk_block[None], mb.deg[None], v[None],
            jnp.asarray([c], jnp.float32), jnp.asarray([lr], jnp.float32),
            jnp.asarray(1, jnp.int32))
    return mb, args


@pytest.mark.distributed
def test_model_tick_sharding_invariance():
    """The panel-sharded tick is shard-count invariant: S in {2, 4, 8}
    matches S=1 to <= 1e-5 for BOTH solver methods (short horizon — the
    per-shard gram partial sums reorder float adds, ~1e-7/step)."""
    g = CASES["weighted"]()
    for method in ("mu_eg", "oja"):
        sched = program.StepSchedule(method=method, degree=3, steps=4,
                                     backend="segment")
        by_s = {}
        for s in (1, 2, 4, 8):
            if s > len(jax.devices()):
                continue
            mesh = _model_mesh(s)
            mb, args = _model_tick_args(g, s)
            tick = program.build_tick_model_sharded(
                sched, mesh, ("model",), mb.block_n, mb.num_chunks,
                mb.block_e)
            out, res = tick(*args)
            by_s[s] = (np.asarray(out), np.asarray(res))
        base_v, base_r = by_s[1]
        assert np.isfinite(base_v).all()
        for s, (v, r) in by_s.items():
            assert np.max(np.abs(v - base_v)) <= TOL, (method, s)
            np.testing.assert_allclose(r, base_r, atol=TOL)


@pytest.mark.distributed
def test_model_tick_one_fused_collective_per_step():
    """Trace-time psum accounting: the mu-EG model tick ships its row
    assembly and 2k x 2k gram in EXACTLY ONE fused (tuple) collective
    per solver step; oja has no gram form and fuses nothing.  Plain
    counts pin the rest of the budget — loop bodies trace ONCE, so the
    traced program holds: one assembly inside the dilation body, the
    final residual apply's dilation body + its own assembly, and (oja
    only) the step's plain row assembly."""
    g = CASES["weighted"]()
    mesh = _model_mesh(2)
    degree = 3
    for method, fused_want, plain_want in (
            ("mu_eg", 1, 3),
            ("oja", 0, 4)):
        sched = program.StepSchedule(method=method, degree=degree,
                                     steps=4, backend="segment")
        mb, args = _model_tick_args(g, 2)
        tick = program.build_tick_model_sharded(
            sched, mesh, ("model",), mb.block_n, mb.num_chunks,
            mb.block_e)
        with program.count_psums() as stats:
            jax.eval_shape(tick, *args)
        assert stats.fused == fused_want, method
        assert stats.plain == plain_want, method


@pytest.mark.distributed
def test_service_model_sharded_tick_equivalence():
    """model_axes serving == single-device segment serving to <= 1e-5
    on weighted / capacity-padded / non-aligned graphs, S in {2, 4, 8},
    including the admission probe routed through the row-sharded matvec
    and update-triggered layout invalidation + rebuild."""
    from repro.stream.service import ServiceConfig, StreamingService

    common = dict(k=4, num_clusters=3, degree=5, steps_per_tick=5,
                  lr=0.3, seed=0, backend="segment")
    single = StreamingService(ServiceConfig(**common))
    sharded = []
    for s in (2, 4, 8):
        if s > len(jax.devices()):
            continue
        sharded.append(StreamingService(ServiceConfig(
            mesh=_model_mesh(s), model_axes=("model",), **common)))
    assert sharded, "distributed marker guarantees >= 2 devices"
    svcs = [single] + sharded
    for sid, g in _service_graphs().items():
        for svc in svcs:
            svc.add_graph(sid, g)
    res = [svc.tick() for svc in svcs]
    for sid in _service_graphs():
        for svc, r in zip(svcs[1:], res[1:]):
            assert abs(r[sid] - res[0][sid]) <= TOL, sid
            err = float(np.max(np.abs(
                np.asarray(svc._sessions[sid].v)
                - np.asarray(single._sessions[sid].v))))
            assert err <= TOL, (sid, err)
    # updates stale the destination-aligned layouts; ticks re-glue
    for svc in svcs:
        svc.apply_updates("weighted", [[0, 5], [1, 7]], [1.0, 1.0])
    assert sharded[0]._sessions["weighted"].model_blocking is None
    for svc in svcs:
        svc.tick()
    assert sharded[0]._sessions["weighted"].model_blocking is not None
    for svc in svcs[1:]:
        err = float(np.max(np.abs(
            np.asarray(svc._sessions["weighted"].v)
            - np.asarray(single._sessions["weighted"].v))))
        assert err <= TOL, err
    # one compiled program per (class, degree, layout, occupancy bucket)
    assert sharded[0].compile_count == len(
        {s.group_key for s in sharded[0]._sessions.values()})


@pytest.mark.distributed
def test_million_node_model_sharded_tick():
    """Million-node acceptance row: n = 1e6, E ~ 5e7 power-law edges
    (alpha = 2.5 — the hub-skewed regime the CSR chunk layout exists
    for) admitted, planned, and ticked end-to-end through the
    panel-sharded service on the 8-virtual-device lane.  Lean knobs
    (degree budget 1, k = 3, 2 steps, probe off) keep this a wall-time
    test of the SCALE path, not of convergence."""
    from repro.stream import service as service_mod
    from repro.stream.service import ServiceConfig, StreamingService

    n = 1_000_000
    g = graphs.power_law_graph(n, avg_degree=100.0, alpha=2.5, seed=0,
                               dedup=False)
    assert g.num_edges >= 45_000_000
    num_shards = min(8, len(jax.devices()))
    svc = StreamingService(ServiceConfig(
        backend="segment", mesh=_model_mesh(num_shards),
        model_axes=("model",), probe_spectrum=False,
        k=3, num_clusters=2, degree=1, steps_per_tick=2, seed=0))
    # pin the ladder's top class outright: the default 1.5x admission
    # headroom would walk past it at 5e7 live edges
    from repro.stream import graph_store as gs
    svc.add_graph("web", g, edge_capacity=gs.CAPACITY_CLASSES[-1])
    res = svc.tick()["web"]
    assert np.isfinite(res)
    sess = svc._sessions["web"]
    # the panel lives at the node-capacity class (pow2 >= n); the real
    # graph occupies the first n rows
    v = np.asarray(sess.v)
    assert v.shape == (service_mod.node_capacity_class(n), 3)
    assert np.isfinite(v[:n]).all()
    # the layout really shards: every shard owns rows, and the skewed
    # half-edge mass spreads without any per-shard edge-balance contract
    mb = sess.model_blocking
    assert mb.num_shards == num_shards
    assert mb.rows_per_shard * num_shards >= n
    assert mb.padded_half_edges >= 2 * g.num_edges
