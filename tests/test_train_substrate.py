"""Optimizer / checkpoint / data-pipeline / fault-tolerance tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # long-running; excluded from scripts/ci.sh fast lane

from repro.data.pipeline import EdgePipeline, TokenPipeline
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import optimizer as opt


def quadratic_params():
    return {"w": jnp.asarray([2.0, -3.0, 1.0]), "b": jnp.asarray(0.5)}


def test_adamw_minimizes_quadratic():
    cfg = opt.OptConfig(lr=0.05, warmup_steps=5, total_steps=400,
                        weight_decay=0.0, clip_norm=10.0)
    params = quadratic_params()
    state = opt.init(cfg, params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(400):
        g = jax.grad(loss_fn)(params)
        params, state, m = opt.apply(cfg, state, params, g)
    assert float(loss_fn(params)) < 1e-3


def test_grad_compression_error_feedback_converges():
    """int8 + error feedback must still drive the loss down (the error
    residual guarantees the long-run average update is unbiased)."""
    cfg = opt.OptConfig(lr=0.05, warmup_steps=0, total_steps=600,
                        weight_decay=0.0, compress_grads=True)
    params = quadratic_params()
    state = opt.init(cfg, params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(600):
        g = jax.grad(loss_fn)(params)
        params, state, _ = opt.apply(cfg, state, params, g)
    assert float(loss_fn(params)) < 5e-3


def test_compression_roundtrip_residual():
    g = jnp.asarray([1.0, -0.5, 0.001])
    err = jnp.zeros(3)
    g_hat, new_err = opt.compress_decompress(g, err)
    np.testing.assert_allclose(g_hat + new_err, g, atol=1e-6)


def test_schedule_shape():
    cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(opt.schedule(cfg, 0)) == 0.0
    assert abs(float(opt.schedule(cfg, 10)) - 1.0) < 1e-6
    assert float(opt.schedule(cfg, 100)) <= cfg.min_lr_frac + 1e-6


# --- checkpointing ---------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree, extra={"cursor": 7})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = ckpt.restore(d, like)
    assert extra["cursor"] == 7
    np.testing.assert_allclose(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc_keeps_last(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.zeros(2)}
    for s in range(5):
        ckpt.save(d, s, tree, keep_last=2)
    steps = sorted(os.listdir(d))
    assert steps == ["step_000000003", "step_000000004"]


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.arange(8).astype(jnp.float32)}
    path = ckpt.save(d, 1, tree)
    # flip a byte in the array payload
    fn = os.path.join(path, "arr_00000.npy")
    raw = bytearray(open(fn, "rb").read())
    raw[-1] ^= 0xFF
    open(fn, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        ckpt.restore(d, jax.tree.map(jnp.zeros_like, tree))


def test_restore_with_fallback_skips_corrupt(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.arange(4).astype(jnp.float32)}
    ckpt.save(d, 1, {"x": tree["x"] * 1}, keep_last=5)
    path2 = ckpt.save(d, 2, {"x": tree["x"] * 2}, keep_last=5)
    fn = os.path.join(path2, "arr_00000.npy")
    raw = bytearray(open(fn, "rb").read())
    raw[-1] ^= 0xFF
    open(fn, "wb").write(bytes(raw))
    restored, _, step = ckpt.restore_with_fallback(
        d, jax.tree.map(jnp.zeros_like, tree))
    assert step == 1  # fell back past the corrupt step 2
    np.testing.assert_allclose(restored["x"], tree["x"])


# --- data pipeline ----------------------------------------------------------

def test_token_pipeline_deterministic_and_shardable():
    pipe = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    b1 = pipe.batch_at(10)
    b2 = pipe.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(pipe.batch_at(11)["tokens"], b1["tokens"])
    # shard determinism: same (seed, step, shard) -> same rows
    s0 = pipe.shard_batch_at(10, 0, 4)
    s0b = pipe.shard_batch_at(10, 0, 4)
    np.testing.assert_array_equal(s0["tokens"], s0b["tokens"])
    assert s0["tokens"].shape == (2, 16)


def test_edge_pipeline_unbiased_mean():
    from repro.core import graphs, laplacian_dense
    g, _ = graphs.ring_of_cliques(3, 5)
    pipe = EdgePipeline(graph=g, batch_edges=64, seed=0)
    batches = [pipe.batch_at(t) for t in range(200)]
    # mean minibatch laplacian ~ true laplacian
    from repro.core import minibatch_laplacian_matvec
    v = jax.random.normal(jax.random.PRNGKey(0), (g.num_nodes, 2))
    acc = jnp.zeros_like(v)
    for b in batches:
        acc = acc + minibatch_laplacian_matvec(
            b["src"], b["dst"], b["weight"], v, b["num_edges_total"])
    want = laplacian_dense(g) @ v
    rel = jnp.linalg.norm(acc / len(batches) - want) / jnp.linalg.norm(want)
    assert float(rel) < 0.1


# --- fault tolerance --------------------------------------------------------

def test_elastic_mesh_single_device():
    mesh, dropped = fault.elastic_mesh(model_axis=16)
    assert mesh.shape["model"] == 1  # gcd(16, 1)
    assert not dropped


def test_straggler_scale():
    s = fault.straggler_scale(jnp.asarray(3), 4)
    assert float(s) == pytest.approx(4 / 3)


def test_retrying_eventually_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise IOError("transient")
        return "ok"

    assert fault.retrying(flaky, attempts=5, base_delay=0.01)() == "ok"


def test_heartbeat_monitor():
    hb = fault.HeartbeatMonitor(num_hosts=3, timeout_s=0.0)
    import time
    time.sleep(0.01)
    hb.beat(1)
    dead = hb.dead_hosts()
    assert 0 in dead and 2 in dead and 1 not in dead or 1 in dead
