"""Serving layer: SLO metrics, the versioned results store, the
double-buffered async ingest/tick pipeline (including the threaded
concurrency suite), and the stdlib HTTP front end."""
import json
import threading
import urllib.error
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graphs
from repro.core.kmeans import cluster_agreement
from repro.serve import Server, ServerConfig, VersionedResults
from repro.serve.http import ServeHTTP
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.stream.service import ServiceConfig, UnknownSessionError

SERVE_SVC = ServiceConfig(k=4, num_clusters=3, degree=7, steps_per_tick=25,
                          lr=0.3, tol=5e-3, dilation_strength=6.0)


def _sbm_edges(seed: int, n: int = 60):
    g, truth = graphs.sbm_graph(n, 3, p_in=0.4, p_out=0.02, seed=seed)
    edges = np.stack([np.asarray(g.src), np.asarray(g.dst)], axis=1)
    return edges, np.asarray(g.weight), n, truth


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_latency_histogram_percentiles_conservative():
    h = LatencyHistogram()
    samples = [1e-5] * 98 + [0.5, 0.9]
    for s in samples:
        h.record(s)
    assert h.count == 100
    # the reported quantile is the holding bucket's UPPER edge: at least
    # the true quantile (SLO-conservative), within one bucket factor
    from repro.serve.metrics import LATENCY_BUCKET_FACTOR as F
    assert 1e-5 <= h.percentile(0.50) <= 1e-5 * F
    assert 0.5 <= h.percentile(0.99) <= 0.5 * F  # 99th of 100 = 0.5
    assert 0.9 <= h.percentile(1.0) <= 0.9 * F
    assert h.percentile(0.0) > 0.0  # min sample's bucket, not 0
    assert h.max_s == 0.9
    assert abs(h.mean_s - np.mean(samples)) < 1e-9
    with pytest.raises(ValueError):
        h.percentile(1.5)
    assert LatencyHistogram().percentile(0.99) == 0.0  # empty => 0


def test_serve_metrics_aggregate_threaded():
    m = ServeMetrics(("push", "labels"))

    def hammer():
        for _ in range(200):
            m.record("push", 2e-6)
            m.inc("staged_batches")

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = m.snapshot()
    assert snap["counters"]["staged_batches"] == 800
    assert snap["latency"]["push"]["count"] == 800
    assert snap["latency"]["labels"]["count"] == 0
    with m.timed("labels"):
        pass
    assert m.percentile("labels", 0.5) > 0.0
    assert m.percentile("nope", 0.5) == 0.0


# ---------------------------------------------------------------------------
# versioned results store
# ---------------------------------------------------------------------------

def test_versioned_results_monotone_versions_and_lazy_labels():
    store = VersionedResults()
    store.register("a", 3)
    with pytest.raises(ValueError):
        store.register("a", 3)  # live duplicate
    with pytest.raises(UnknownSessionError):
        store.commit("ghost", {}, None)
    panel = np.eye(4)
    calls = []

    def labeler(p):
        calls.append(1)
        return np.asarray([0, 1, 2, 0])

    assert store.commit("a", {"residual": 1.0}, panel) == 1
    assert store.commit("a", {"residual": 0.5}, panel) == 2
    assert store.version("a") == 2
    assert store.summary("a")["version"] == 2  # summary carries version
    lab, version, churn = store.labels("a", labeler)
    assert version == 2 and churn == 0.0
    np.testing.assert_array_equal(lab, [0, 1, 2, 0])
    store.labels("a", labeler)
    assert len(calls) == 1  # cached: one labeler run per version
    # a permuted relabelling of the next version serves STABLE ids
    store.commit("a", {"residual": 0.4}, panel)
    lab2, version2, churn2 = store.labels(
        "a", lambda p: np.asarray([1, 2, 0, 1]))  # same partition, permuted
    assert version2 == 3
    np.testing.assert_array_equal(lab2, lab)  # tracker mapped ids back
    assert churn2 == 0.0  # measured guarantee: no genuine movement
    # eviction tombstones: reads 404 but re-registration works
    store.evict("a")
    with pytest.raises(UnknownSessionError):
        store.summary("a")
    with pytest.raises(UnknownSessionError):
        store.evict("a")  # not idempotent, same as the engine
    store.register("a", 3)
    assert store.commit("a", {}, panel) == 1  # fresh lineage
    assert store.stats()["commits"] == 4


# ---------------------------------------------------------------------------
# server (manual stepping: deterministic pipeline semantics)
# ---------------------------------------------------------------------------

def test_server_pipeline_manual_steps_end_to_end():
    srv = Server(ServerConfig(service=SERVE_SVC))
    edges, w, n, truth = _sbm_edges(11)
    out = srv.admit("a", edges, n, weights=w, num_clusters=3,
                    edge_capacity=1024)
    assert out["version"] == 1  # queryable before the first tick
    # staging alone must not touch the engine: no compiles, no version
    c0 = srv.service.compile_count
    for i in range(6):
        r = srv.push("a", [[i, i + 1]], [0.5], mode="add")
        assert r["staged"] == 1 and r["applied"] == 0
    assert srv.service.compile_count == c0
    assert srv.results.version("a") == 1
    assert r["queue_depth"] == 6
    # drain + tick until converged
    for _ in range(200):
        srv.step()
        if srv.service.all_converged:
            break
    assert srv.service.all_converged
    lab = srv.labels("a")
    assert lab["version"] > 1
    agree = float(cluster_agreement(jnp.asarray(lab["labels"]),
                                    jnp.asarray(truth), 3))
    assert agree > 0.9
    # repeated query at one version: identical bytes, zero churn
    again = srv.labels("a")
    assert again["version"] == lab["version"]
    np.testing.assert_array_equal(again["labels"], lab["labels"])
    s = srv.summary("a")
    assert s["converged"] and s["version"] == lab["version"]
    # staged batches all landed
    m = srv.metrics
    assert m.counter("applied_batches") > 0
    assert m.counter("dropped_batches") == 0
    ev = srv.evict("a")
    assert np.asarray(ev["panel"]).shape[0] == n  # resumable panel
    for fn in (lambda: srv.labels("a"), lambda: srv.summary("a"),
               lambda: srv.evict("a"),
               lambda: srv.push("a", [[0, 1]], [1.0])):
        with pytest.raises(UnknownSessionError):
            fn()
    # a batch staged just before eviction is dropped, not applied
    srv.admit("b", edges, n, weights=w, edge_capacity=1024)
    srv.push("b", [[0, 1]], [1.0])
    srv.evict("b")
    assert m.counter("dropped_batches") == 1


def test_server_serialized_pipeline_applies_inline():
    srv = Server(ServerConfig(service=SERVE_SVC, pipeline="serialized"))
    edges, w, n, _ = _sbm_edges(12)
    srv.admit("s", edges, n, weights=w, edge_capacity=1024)
    r = srv.push("s", [[0, 1]], [0.5], mode="add")
    # the baseline has no staging: the batch applies under the engine
    # lock and commits a fresh version before returning
    assert r["staged"] == 0 and r["applied"] == 1
    assert r["version"] == 2 == srv.results.version("s")
    with pytest.raises(ValueError):
        srv.push("s", [[0, 1]], [1.0], mode="xor")
    with pytest.raises(ValueError):
        srv.push("s", [[0, 1]], [1.0, 2.0])  # length mismatch
    with pytest.raises(ValueError):
        ServerConfig(pipeline="bogus")


def test_server_drains_capacity_classes_through_one_pad():
    """The drain groups staged sessions by capacity class and pins ONE
    pow2 batch pad per class, so every member's apply hits the same
    compiled edge-batch program (keyed on capacity, pad, mode) — one
    compile per class, not one per pow2 batch size per session.  A
    different-capacity session forms its own class, and the padded
    applies land identically to the serialized pipeline's unpadded
    inline applies."""
    srv = Server(ServerConfig(service=SERVE_SVC))
    base = Server(ServerConfig(service=SERVE_SVC, pipeline="serialized"))
    edges, w, n, _ = _sbm_edges(21)
    for s in (srv, base):
        s.admit("a", edges, n, weights=w, edge_capacity=1024)
        s.admit("b", edges, n, weights=w, edge_capacity=1024)
        s.admit("c", edges, n, weights=w, edge_capacity=2048)
    assert (srv.service.capacity_class("a")
            == srv.service.capacity_class("b")
            != srv.service.capacity_class("c"))
    # different batch sizes inside the shared class: the class pad is
    # the pow2 of the largest, so both applies share one batch shape
    pushes = [("a", [[0, 5], [1, 6], [2, 7]]), ("b", [[3, 8]]),
              ("c", [[4, 9]])]
    for s in (srv, base):
        for sid, es in pushes:
            s.push(sid, es, [0.5] * len(es), mode="add")
    srv.step()
    assert srv.metrics.counter("drain_classes") == 2  # {a, b} and {c}
    assert srv.metrics.counter("applied_batches") == 3
    assert srv.metrics.counter("dropped_batches") == 0
    # padding is a no-op on the stores: padded slots carry zero weight
    for sid in ("a", "b", "c"):
        np.testing.assert_array_equal(
            np.asarray(srv.service._sessions[sid].store.weight),
            np.asarray(base.service._sessions[sid].store.weight))
        np.testing.assert_array_equal(
            np.asarray(srv.service._sessions[sid].store.src),
            np.asarray(base.service._sessions[sid].store.src))


# ---------------------------------------------------------------------------
# concurrency: threaded ingest + queries against a live engine thread
# ---------------------------------------------------------------------------

def test_server_concurrent_ingest_no_lost_updates():
    """Interleaved push/query threads against the running engine:
    every `add` lands exactly once (weights prove it), served result
    versions never go backwards, and staging stays compile-free."""
    srv = Server(ServerConfig(service=SERVE_SVC, idle_sleep_s=0.001))
    edges, w, n, _ = _sbm_edges(13)
    # the accounting session: a path graph whose high node ids are
    # untouched, so each pusher thread owns fresh (40+t, 41+t) slots
    path = np.stack([np.arange(19), np.arange(1, 20)], axis=1)
    with srv:
        srv.admit("query", edges, n, weights=w, num_clusters=3,
                  edge_capacity=1024)
        srv.admit("acc", path, 60, num_clusters=3, edge_capacity=1024)
        pushes_per_thread, num_push = 25, 4
        errors = []
        versions = []

        def pusher(t):
            try:
                for _ in range(pushes_per_thread):
                    srv.push("acc", [[40 + t, 41 + t]], [1.0], mode="add")
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def querier():
            try:
                seen = []
                for _ in range(60):
                    seen.append(srv.summary("query")["version"])
                    srv.labels("query")
                versions.append(seen)
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = ([threading.Thread(target=pusher, args=(t,))
                    for t in range(num_push)]
                   + [threading.Thread(target=querier) for _ in range(2)])
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        assert srv.flush(timeout=60.0)
        # no lost updates: thread t's accumulated weight is exact
        src, dst, ws = srv.service.live_edges("acc")
        got = {(int(a), int(b)): float(x)
               for a, b, x in zip(src, dst, ws)}
        for t in range(num_push):
            assert got[(40 + t, 41 + t)] == pushes_per_thread, (t, got)
        # versions observed by query threads never went backwards
        for seen in versions:
            assert all(a <= b for a, b in zip(seen, seen[1:])), seen
        # accounting closes: everything staged was applied (coalesced
        # drains may batch many staged pushes into one apply)
        mc = srv.metrics
        assert mc.counter("staged_batches") == pushes_per_thread * num_push
        assert mc.counter("applied_batches") >= 1
        assert mc.counter("dropped_batches") == 0
        assert srv.wait_converged(timeout=120.0)
        # one capacity class end to end: the pipeline added no compiles
        # beyond the engine's pow2 occupancy buckets
        assert len({key for key, _ in srv.service._compiled}) == 1
    assert not srv.running  # context exit drained and stopped cleanly
    snap = srv.stats()
    assert snap["latency"]["push"]["count"] == 100
    assert snap["latency"]["push"]["p99_s"] > 0.0
    assert snap["gauges"]["tick_utilization"] > 0.0


# ---------------------------------------------------------------------------
# HTTP front end
# ---------------------------------------------------------------------------

def _req(url, method="GET", body=None):
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        url, data=data, method=method,
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_http_roundtrip_and_error_mapping():
    edges, w, n, truth = _sbm_edges(14)
    with ServeHTTP(Server(ServerConfig(service=SERVE_SVC))) as front:
        base = f"http://{front.host}:{front.port}"
        assert _req(base + "/healthz")[0] == 200
        code, out = _req(base + f"/v1/sessions/h1", "POST",
                         {"edges": edges.tolist(), "num_nodes": n,
                          "weights": w.tolist(), "num_clusters": 3,
                          "edge_capacity": 1024})
        assert code == 200 and out["version"] == 1
        code, out = _req(base + "/v1/sessions/h1/edges", "POST",
                         {"edges": [[0, 1]], "weights": [0.5],
                          "mode": "add"})
        assert code == 200 and out["staged"] == 1
        assert front.app.wait_converged(timeout=120.0)
        code, out = _req(base + "/v1/sessions/h1/labels")
        assert code == 200 and out["version"] >= 1
        agree = float(cluster_agreement(jnp.asarray(out["labels"]),
                                        jnp.asarray(truth), 3))
        assert agree > 0.9
        code, out = _req(base + "/v1/sessions/h1")
        assert code == 200 and out["converged"]
        code, out = _req(base + "/metrics")
        assert code == 200
        assert out["latency"]["push"]["count"] == 1
        assert out["engine"]["sessions"] == 1
        # error mapping: 404 unknown sid, 400 malformed, 404 bad route
        assert _req(base + "/v1/sessions/ghost/labels")[0] == 404
        assert _req(base + "/v1/sessions/ghost", "DELETE")[0] == 404
        assert _req(base + "/v1/sessions/h1/edges", "POST",
                    {"edges": [[0, 1]]})[0] == 400
        assert _req(base + "/v1/sessions/zz", "POST",
                    {"edges": [[0, 1]]})[0] == 400  # missing num_nodes
        assert _req(base + "/nope")[0] == 404
        code, out = _req(base + "/v1/sessions/h1", "DELETE")
        assert code == 200 and "panel" not in out  # stripped on the wire
        assert _req(base + "/v1/sessions/h1")[0] == 404
